"""Convergence behaviour of Algorithm 1 (Theorems 1-2, Lemma 1-2, Fig. 1/3)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import problems, topology as topo
from repro.core.cola import ColaConfig, cocoa_mixing, run_cola, solve_reference
from repro.data import synthetic


@pytest.fixture(scope="module")
def ridge():
    x, y, _ = synthetic.regression(200, 64, seed=0)
    return problems.ridge_primal(jnp.asarray(x), jnp.asarray(y), 1e-2)


@pytest.fixture(scope="module")
def lasso_prob():
    x, y, _ = synthetic.regression(200, 64, seed=1, sparsity_solution=0.2)
    return problems.lasso(jnp.asarray(x), jnp.asarray(y), 1e-2)


@pytest.fixture(scope="module")
def ridge_opt(ridge):
    return solve_reference(ridge, rounds=1500, kappa=10)


def test_linear_rate_strongly_convex(ridge, ridge_opt):
    """Thm 1: log-suboptimality decreases ~linearly in rounds for ridge."""
    res = run_cola(ridge, topo.ring(8), ColaConfig(kappa=2.0), rounds=120,
                   record_every=20)
    sub = np.array(res.history["primal"]) - ridge_opt + 1e-12
    assert (sub > -1e-6).all()
    logs = np.log(np.maximum(sub, 1e-12))
    # halves of the log-curve drop by comparable amounts (linear rate)
    drop_a = logs[0] - logs[len(logs) // 2]
    drop_b = logs[len(logs) // 2] - logs[-1]
    assert drop_a > 0.5 and drop_b > 0.25


def test_sublinear_general_convex(lasso_prob):
    """Thm 2: lasso duality gap decreases monotonically-ish and is positive."""
    res = run_cola(lasso_prob, topo.ring(8), ColaConfig(kappa=2.0),
                   rounds=150, record_every=25)
    gaps = np.array(res.history["gap"])
    assert gaps[-1] < gaps[0] * 0.05
    assert (gaps > -1e-5).all()


def test_duality_gap_upper_bounds_suboptimality(ridge, ridge_opt):
    res = run_cola(ridge, topo.ring(8), ColaConfig(kappa=1.0), rounds=60,
                   record_every=10)
    for prim, gap in zip(res.history["primal"], res.history["gap"]):
        assert gap >= prim - ridge_opt - 1e-4


def test_lemma1_mean_invariant_and_sandwich(ridge):
    """Lemma 1: (1/K) sum v_k = A x exactly; F_A <= H_A."""
    from repro.core.cola import build_env, init_state, make_round
    from repro.core.duality import gap_report
    from repro.core.partition import make_partition

    k = 8
    part = make_partition(ridge.n, k)
    env = build_env(ridge, part)
    state = init_state(ridge, part)
    rnd = make_round(ridge, part, ColaConfig(kappa=1.0))
    w = jnp.asarray(topo.metropolis_weights(topo.ring(k)), jnp.float32)
    act = jnp.ones((k,), jnp.float32)
    for _ in range(5):
        state = rnd(state, env, w, act)
    x = part.merge_vector(state.x_parts)
    np.testing.assert_allclose(np.asarray(jnp.mean(state.v_stack, axis=0)),
                               np.asarray(ridge.a @ x), rtol=2e-4, atol=2e-5)
    rep = gap_report(ridge, part, state.x_parts, state.v_stack)
    assert float(rep.primal) <= float(rep.hamiltonian) + 1e-5


def test_cocoa_special_case_keeps_consensus(ridge):
    """W = (1/K)11^T: the post-mix estimate v_k^(t+1/2) every node solves its
    subproblem against is the exact consensus v_c = Ax (CoCoA recovered)."""
    res = run_cola(ridge, topo.complete(8), ColaConfig(kappa=1.0), rounds=10,
                   w_override=cocoa_mixing(8))
    from repro.core.mixing import dense_mix
    from repro.core.partition import make_partition
    w = jnp.asarray(cocoa_mixing(8), jnp.float32)
    v_half = np.asarray(dense_mix(w, res.state.v_stack))
    np.testing.assert_allclose(v_half, np.broadcast_to(v_half[:1],
                                                       v_half.shape),
                               atol=1e-4)
    part = make_partition(ridge.n, 8)
    x = part.merge_vector(res.state.x_parts)
    np.testing.assert_allclose(v_half[0], np.asarray(ridge.a @ x), atol=1e-3)


def test_topology_ordering(ridge, ridge_opt):
    """Fig. 3: smaller beta converges faster (complete < ring suboptimality)."""
    rounds = 60
    sub = {}
    for name, g in [("ring", topo.ring(16)), ("complete", topo.complete(16))]:
        res = run_cola(ridge, g, ColaConfig(kappa=1.0), rounds=rounds,
                       record_every=rounds - 1)
        sub[name] = res.history["primal"][-1] - ridge_opt
    assert sub["complete"] <= sub["ring"] + 1e-6


def test_kappa_tradeoff(ridge, ridge_opt):
    """Fig. 1a: more local work per round => fewer rounds to a target."""
    rounds = 40
    subs = []
    for kappa in (0.25, 1.0, 8.0):
        res = run_cola(ridge, topo.ring(8), ColaConfig(kappa=kappa),
                       rounds=rounds, record_every=rounds - 1)
        subs.append(res.history["primal"][-1] - ridge_opt)
    # monotone non-increasing in kappa (saturates once the local subproblem
    # is solved ~exactly and the network term dominates — Fig. 1a plateau)
    tol = 1e-3 * max(abs(subs[0]), 1.0)
    assert subs[2] <= subs[1] + tol <= subs[0] + 2 * tol


def test_consensus_violation_vanishes(ridge):
    res = run_cola(ridge, topo.ring(8), ColaConfig(kappa=2.0), rounds=150,
                   record_every=30)
    cv = res.history["consensus_violation"]
    assert cv[-1] < cv[1] * 0.05


def test_gossip_steps_b_greater_one(ridge, ridge_opt):
    """App. E.2: B=3 gossip steps per round converges at least as fast."""
    r1 = run_cola(ridge, topo.ring(16), ColaConfig(kappa=1.0, gossip_steps=1),
                  rounds=50, record_every=49)
    r3 = run_cola(ridge, topo.ring(16), ColaConfig(kappa=1.0, gossip_steps=3),
                  rounds=50, record_every=49)
    assert (r3.history["primal"][-1] - ridge_opt
            <= r1.history["primal"][-1] - ridge_opt + 1e-6)


def test_hessian_subproblem_variant(ridge, ridge_opt):
    """App. E.1 mixed-gradient subproblem still converges."""
    res = run_cola(ridge, topo.ring(8),
                   ColaConfig(kappa=2.0, grad_mode="mixed"), rounds=80,
                   record_every=79)
    assert res.history["primal"][-1] - ridge_opt < 0.5
