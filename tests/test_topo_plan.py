"""The topology-program compiler: edge coloring, plan IR, mixing semantics.

Mesh-free: ``plan_mix_dense`` is the reference executor, pinned against
``mixing.dense_mix`` (the bitwise oracle for arbitrary graphs) for random
sparse doubly-stochastic W — including churn-reweighted supports — via the
hypothesis property test. The shard_map lowering itself is covered by
``tests/test_dist_plan.py`` (4-virtual-device subprocess + CI mesh job).
"""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import mixing, topology as topo
from repro import topo as rtopo
from repro.topo import coloring


def _random_support(k: int, p: float, seed: int) -> np.ndarray:
    """Random symmetric off-diagonal support with at least one edge."""
    rng = np.random.default_rng(seed)
    up = np.triu(rng.random((k, k)) < p, 1)
    adj = up | up.T
    if not adj.any():
        adj[0, 1] = adj[1, 0] = True
    return adj


# ---------------------------------------------------------------------------
# coloring
# ---------------------------------------------------------------------------

@given(k=st.integers(3, 24), p=st.floats(0.05, 0.9), seed=st.integers(0, 99))
@settings(max_examples=40, deadline=None)
def test_greedy_coloring_is_proper_and_bounded(k, p, seed):
    adj = _random_support(k, p, seed)
    edges = coloring.undirected_edges(adj)
    classes = coloring.greedy_edge_coloring(edges, k)
    # partition: every edge exactly once
    flat = [e for cls in classes for e in cls]
    assert sorted(flat) == sorted(edges)
    # proper: every class is a matching
    for cls in classes:
        coloring.check_matching(cls, k)
    # greedy bound
    delta = int(adj.sum(axis=1).max())
    assert len(classes) <= max(2 * delta - 1, 1)


def test_coloring_deterministic():
    adj = _random_support(12, 0.4, 3)
    a = coloring.greedy_edge_coloring(coloring.undirected_edges(adj), 12)
    b = coloring.greedy_edge_coloring(coloring.undirected_edges(adj), 12)
    assert a == b
    assert rtopo.compile_plan(adj).cache_token() == \
        rtopo.compile_plan(adj).cache_token()


def test_ring_colors_to_two_matchings_even_k():
    plan = rtopo.compile_plan(topo.ring(8))
    assert plan.num_colors == 2
    assert rtopo.compile_plan(topo.ring(7)).num_colors == 3  # odd cycle


# ---------------------------------------------------------------------------
# plan semantics: compiled-plan mixing == dense_mix (the satellite property
# test — random sparse doubly-stochastic W, incl. churn-reweighted supports)
# ---------------------------------------------------------------------------

@given(k=st.integers(2, 16), p=st.floats(0.1, 0.9), seed=st.integers(0, 999),
       drop=st.floats(0.0, 0.5))
@settings(max_examples=50, deadline=None)
def test_plan_mix_equals_dense_mix(k, p, seed, drop):
    """For any random sparse doubly-stochastic W (Metropolis over a random
    support) and any churn reweighting of it, executing the compiled plan
    reproduces the dense (K, K) matmul to float tolerance."""
    rng = np.random.default_rng(seed)
    graph = topo.Topology("rand", _random_support(k, p, seed))
    plan = rtopo.compile_plan(graph)
    v = rng.standard_normal((k, 7)).astype(np.float32)

    w = topo.metropolis_weights(graph)  # doubly stochastic, symmetric
    np.testing.assert_allclose(np.asarray(w.sum(0)), 1.0, atol=1e-12)
    got = np.asarray(rtopo.mix_with_plan(plan, w, v))
    want = np.asarray(mixing.dense_mix(jnp.asarray(w, jnp.float32),
                                       jnp.asarray(v)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)

    # churn-reweighted: support shrinks, same compiled plan executes W_t
    active = rng.random(k) >= drop
    if not active.any():
        active[:] = True
    w_t = topo.reweight_for_active(graph, active)
    got = np.asarray(rtopo.mix_with_plan(plan, w_t, v))
    want = np.asarray(mixing.dense_mix(jnp.asarray(w_t, jnp.float32),
                                       jnp.asarray(v)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_plan_schedule_materializes_like_the_churn_masks():
    graph = topo.torus_2d(2, 3)
    plan = rtopo.compile_plan(graph)
    rng = np.random.default_rng(0)
    t, k = 5, graph.num_nodes
    w_stack = np.stack([
        topo.reweight_for_active(graph, rng.random(k) < 0.8)
        for _ in range(t)]).astype(np.float32)
    ps = rtopo.PlanSchedule.from_w_stack(plan, w_stack)
    assert ps.diag.shape == (t, k)
    assert ps.coefs.shape == (t, plan.num_colors, k)
    v = rng.standard_normal((k, 4)).astype(np.float32)
    for t_i in range(t):
        got = rtopo.plan_mix_dense(plan, ps.diag[t_i], ps.coefs[t_i], v)
        want = mixing.dense_mix(jnp.asarray(w_stack[t_i]), jnp.asarray(v))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-6)
    # static form: broadcast views, O(C*K) memory
    static = rtopo.PlanSchedule.from_w_stack(
        plan, np.broadcast_to(w_stack[0], (t, k, k)), static=True)
    assert static.coefs.base is not None  # a view, not t copies
    np.testing.assert_array_equal(static.coefs[0], static.coefs[-1])


def test_plan_coverage_validation():
    """W mass outside the compiled support must raise, not silently drop —
    the generalization of mixing.check_circulant_band."""
    plan = rtopo.compile_plan(topo.ring(6))
    w_bad = topo.metropolis_weights(topo.connected_cycle(6, 2))
    with pytest.raises(ValueError, match="outside the compiled plan"):
        rtopo.check_plan_covers(plan, w_bad)
    with pytest.raises(ValueError, match="outside the compiled plan"):
        rtopo.plan_coefficients(plan, w_bad)
    # subsets are fine (churn only removes edges)
    act = np.array([1, 1, 0, 1, 1, 1], dtype=bool)
    rtopo.plan_coefficients(plan, topo.reweight_for_active(topo.ring(6), act))
    with pytest.raises(ValueError, match="does not match"):
        rtopo.check_plan_covers(plan, np.eye(4))


def test_plan_byte_accounting_and_render():
    plan = rtopo.compile_plan(topo.torus_2d(4, 4))
    d, item = 64, 4
    assert plan.bytes_per_link_per_step(d, item) == 2 * d * item
    assert plan.bytes_per_device_per_step(d, item) == \
        plan.num_colors * d * item
    assert plan.total_bytes_per_step(d, item) == \
        plan.num_edges * 2 * d * item
    text = plan.render(d=d, itemsize=item)
    assert "colors=" in text and "bytes/round" in text
    assert f"K={plan.num_nodes}" in text


def test_plan_support_roundtrip():
    graph = rtopo.hypercube(8)
    plan = rtopo.compile_plan(graph)
    np.testing.assert_array_equal(plan.support(), graph.adjacency)
    assert plan.max_degree() == 3
    assert plan.num_edges == graph.adjacency.sum() // 2


# ---------------------------------------------------------------------------
# graph builders
# ---------------------------------------------------------------------------

def test_expander_builder():
    g = rtopo.expander(16, degree=4, seed=1)
    assert rtopo.graphs.is_connected(g.adjacency)
    assert g.adjacency.sum(axis=1).max() <= 4 + 1
    w = topo.metropolis_weights(g)
    assert topo.spectral_gap(w) > topo.spectral_gap(
        topo.metropolis_weights(topo.ring(16)))  # expanders mix faster
    # deterministic in seed
    np.testing.assert_array_equal(
        g.adjacency, rtopo.expander(16, degree=4, seed=1).adjacency)


def test_random_geometric_builder():
    g = rtopo.random_geometric(20, seed=3)
    assert rtopo.graphs.is_connected(g.adjacency)
    assert (g.adjacency == g.adjacency.T).all()
    with pytest.raises(ValueError, match="disconnected"):
        rtopo.random_geometric(20, radius=1e-3, seed=3)


def test_hypercube_builder():
    g = rtopo.hypercube(16)
    assert (g.adjacency.sum(axis=1) == 4).all()
    with pytest.raises(ValueError):
        rtopo.hypercube(12)


def test_registry_builds_all():
    for name in sorted(rtopo.GRAPHS):
        g = rtopo.build(name, 16)
        assert g.num_nodes == 16
        plan = rtopo.compile_plan(g)
        if name != "disconnected":
            assert plan.num_edges > 0
    with pytest.raises(ValueError, match="unknown topology"):
        rtopo.build("moebius", 16)
