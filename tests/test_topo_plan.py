"""The topology-program compiler: edge coloring, plan IR, mixing semantics.

Mesh-free: ``plan_mix_dense`` / ``block_mix_dense`` are the reference
executors, pinned against ``mixing.dense_mix`` (the oracle for arbitrary
graphs; BITWISE in block mode) for random sparse doubly-stochastic W —
including churn-reweighted supports — via the hypothesis property tests.
The coloring wall validates greedy AND Misra–Gries through
``check_coloring`` (proper + exact partition) and pins the Vizing bound:
Misra–Gries never exceeds Delta + 1, including the odd-complete-K
regression where greedy does. The shard_map lowering itself is covered by
``tests/test_dist_plan.py`` / ``test_dist_parity.py`` (4-virtual-device
subprocess + CI mesh job).
"""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import mixing, topology as topo
from repro import topo as rtopo
from repro.topo import coloring


def _random_support(k: int, p: float, seed: int) -> np.ndarray:
    """Random symmetric off-diagonal support with at least one edge."""
    rng = np.random.default_rng(seed)
    up = np.triu(rng.random((k, k)) < p, 1)
    adj = up | up.T
    if not adj.any():
        adj[0, 1] = adj[1, 0] = True
    return adj


# ---------------------------------------------------------------------------
# coloring
# ---------------------------------------------------------------------------

@given(k=st.integers(3, 24), p=st.floats(0.05, 0.9), seed=st.integers(0, 99))
@settings(max_examples=40, deadline=None)
def test_greedy_coloring_is_proper_and_bounded(k, p, seed):
    adj = _random_support(k, p, seed)
    edges = coloring.undirected_edges(adj)
    classes = coloring.greedy_edge_coloring(edges, k)
    # proper coloring + exact edge partition, via the shared validator
    coloring.check_coloring(classes, edges, k)
    # greedy bound
    delta = int(adj.sum(axis=1).max())
    assert len(classes) <= max(2 * delta - 1, 1)


@given(k=st.integers(3, 24), p=st.floats(0.05, 0.9), seed=st.integers(0, 99))
@settings(max_examples=40, deadline=None)
def test_misra_gries_is_proper_and_vizing_bounded(k, p, seed):
    """The satellite property wall: Misra–Gries is a proper edge coloring
    with AT MOST Delta + 1 classes on random graphs — the Vizing bound the
    greedy pass can exceed — and the 'auto' pass inherits the bound."""
    adj = _random_support(k, p, seed)
    edges = coloring.undirected_edges(adj)
    delta = int(adj.sum(axis=1).max())
    mg = coloring.misra_gries_edge_coloring(edges, k)
    coloring.check_coloring(mg, edges, k)
    assert len(mg) <= delta + 1
    auto = coloring.edge_coloring(edges, k)  # the compile_plan default
    coloring.check_coloring(auto, edges, k)
    assert len(auto) <= delta + 1


@pytest.mark.parametrize("k", [5, 9, 11, 13])
def test_odd_complete_regression_greedy_exceeds_vizing(k):
    """K_n for odd n is the regression motivating Misra–Gries: chi' = n =
    Delta + 1, greedy lands strictly above it (extra ppermutes per gossip
    step), Misra–Gries exactly on it — and the default compile path takes
    the Misra–Gries result."""
    adj = topo.complete(k).adjacency
    edges = coloring.undirected_edges(adj)
    delta = k - 1
    greedy = coloring.greedy_edge_coloring(edges, k)
    coloring.check_coloring(greedy, edges, k)
    assert len(greedy) > delta + 1  # the regression
    mg = coloring.misra_gries_edge_coloring(edges, k)
    coloring.check_coloring(mg, edges, k)
    assert len(mg) == delta + 1  # Vizing-optimal (chi'(K_odd) = n)
    assert rtopo.compile_plan(adj).num_colors == delta + 1


def test_edge_coloring_methods():
    adj = topo.complete(5).adjacency
    edges = coloring.undirected_edges(adj)
    assert coloring.edge_coloring(edges, 5, method="greedy") == \
        coloring.greedy_edge_coloring(edges, 5)
    assert coloring.edge_coloring(edges, 5, method="mg") == \
        coloring.misra_gries_edge_coloring(edges, 5)
    with pytest.raises(ValueError, match="unknown coloring method"):
        coloring.edge_coloring(edges, 5, method="rainbow")
    with pytest.raises(ValueError, match="not a matching"):
        coloring.check_coloring([[(0, 1), (1, 2)]], [(0, 1), (1, 2)], 3)
    with pytest.raises(ValueError, match="partition"):
        coloring.check_coloring([[(0, 1)]], [(0, 1), (1, 2)], 3)


def test_coloring_deterministic():
    adj = _random_support(12, 0.4, 3)
    a = coloring.greedy_edge_coloring(coloring.undirected_edges(adj), 12)
    b = coloring.greedy_edge_coloring(coloring.undirected_edges(adj), 12)
    assert a == b
    mg_a = coloring.misra_gries_edge_coloring(
        coloring.undirected_edges(adj), 12)
    mg_b = coloring.misra_gries_edge_coloring(
        coloring.undirected_edges(adj), 12)
    assert mg_a == mg_b
    assert rtopo.compile_plan(adj).cache_token() == \
        rtopo.compile_plan(adj).cache_token()


def test_ring_colors_to_two_matchings_even_k():
    # 'auto' keeps greedy's Delta-optimal 2 matchings on the even ring
    plan = rtopo.compile_plan(topo.ring(8))
    assert plan.num_colors == 2
    assert rtopo.compile_plan(topo.ring(7)).num_colors == 3  # odd cycle


# ---------------------------------------------------------------------------
# plan semantics: compiled-plan mixing == dense_mix (the satellite property
# test — random sparse doubly-stochastic W, incl. churn-reweighted supports)
# ---------------------------------------------------------------------------

@given(k=st.integers(2, 16), p=st.floats(0.1, 0.9), seed=st.integers(0, 999),
       drop=st.floats(0.0, 0.5))
@settings(max_examples=50, deadline=None)
def test_plan_mix_equals_dense_mix(k, p, seed, drop):
    """For any random sparse doubly-stochastic W (Metropolis over a random
    support) and any churn reweighting of it, executing the compiled plan
    reproduces the dense (K, K) matmul to float tolerance."""
    rng = np.random.default_rng(seed)
    graph = topo.Topology("rand", _random_support(k, p, seed))
    plan = rtopo.compile_plan(graph)
    v = rng.standard_normal((k, 7)).astype(np.float32)

    w = topo.metropolis_weights(graph)  # doubly stochastic, symmetric
    np.testing.assert_allclose(np.asarray(w.sum(0)), 1.0, atol=1e-12)
    got = np.asarray(rtopo.mix_with_plan(plan, w, v))
    want = np.asarray(mixing.dense_mix(jnp.asarray(w, jnp.float32),
                                       jnp.asarray(v)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)

    # churn-reweighted: support shrinks, same compiled plan executes W_t
    active = rng.random(k) >= drop
    if not active.any():
        active[:] = True
    w_t = topo.reweight_for_active(graph, active)
    got = np.asarray(rtopo.mix_with_plan(plan, w_t, v))
    want = np.asarray(mixing.dense_mix(jnp.asarray(w_t, jnp.float32),
                                       jnp.asarray(v)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_plan_schedule_materializes_like_the_churn_masks():
    graph = topo.torus_2d(2, 3)
    plan = rtopo.compile_plan(graph)
    rng = np.random.default_rng(0)
    t, k = 5, graph.num_nodes
    w_stack = np.stack([
        topo.reweight_for_active(graph, rng.random(k) < 0.8)
        for _ in range(t)]).astype(np.float32)
    ps = rtopo.PlanSchedule.from_w_stack(plan, w_stack)
    assert ps.diag.shape == (t, k)
    assert ps.coefs.shape == (t, plan.num_colors, k)
    v = rng.standard_normal((k, 4)).astype(np.float32)
    for t_i in range(t):
        got = rtopo.plan_mix_dense(plan, ps.diag[t_i], ps.coefs[t_i], v)
        want = mixing.dense_mix(jnp.asarray(w_stack[t_i]), jnp.asarray(v))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-6)
    # static form: broadcast views, O(C*K) memory
    static = rtopo.PlanSchedule.from_w_stack(
        plan, np.broadcast_to(w_stack[0], (t, k, k)), static=True)
    assert static.coefs.base is not None  # a view, not t copies
    np.testing.assert_array_equal(static.coefs[0], static.coefs[-1])


def test_plan_coverage_validation():
    """W mass outside the compiled support must raise, not silently drop —
    the generalization of mixing.check_circulant_band."""
    plan = rtopo.compile_plan(topo.ring(6))
    w_bad = topo.metropolis_weights(topo.connected_cycle(6, 2))
    with pytest.raises(ValueError, match="outside the compiled plan"):
        rtopo.check_plan_covers(plan, w_bad)
    with pytest.raises(ValueError, match="outside the compiled plan"):
        rtopo.plan_coefficients(plan, w_bad)
    # subsets are fine (churn only removes edges)
    act = np.array([1, 1, 0, 1, 1, 1], dtype=bool)
    rtopo.plan_coefficients(plan, topo.reweight_for_active(topo.ring(6), act))
    with pytest.raises(ValueError, match="does not match"):
        rtopo.check_plan_covers(plan, np.eye(4))


def test_plan_byte_accounting_and_render():
    plan = rtopo.compile_plan(topo.torus_2d(4, 4))
    d, item = 64, 4
    assert plan.bytes_per_link_per_step(d, item) == 2 * d * item
    assert plan.bytes_per_device_per_step(d, item) == \
        plan.num_colors * d * item
    assert plan.total_bytes_per_step(d, item) == \
        plan.num_edges * 2 * d * item
    text = plan.render(d=d, itemsize=item)
    assert "colors=" in text and "bytes/round" in text
    assert f"K={plan.num_nodes}" in text


def test_plan_support_roundtrip():
    graph = rtopo.hypercube(8)
    plan = rtopo.compile_plan(graph)
    np.testing.assert_array_equal(plan.support(), graph.adjacency)
    assert plan.max_degree() == 3
    assert plan.num_edges == graph.adjacency.sum() // 2


@given(k=st.integers(4, 20), p=st.floats(0.1, 0.9), seed=st.integers(0, 99))
@settings(max_examples=30, deadline=None)
def test_w_from_coefficients_inverts_plan_coefficients(k, p, seed):
    """`w_from_coefficients` is the exact inverse of `plan_coefficients`:
    lower any in-support W (including churn-reweighted supports) to
    (diag, coefs) and scattering back reproduces W bitwise — what the
    telemetry gate recompute relies on when the per-node CommPlan path has
    dropped the (T, K, K) stack."""
    rng = np.random.default_rng(seed)
    graph = topo.Topology("rand", _random_support(k, p, seed))
    plan = rtopo.compile_plan(graph)
    w = np.asarray(topo.metropolis_weights(graph))
    diag, coefs = rtopo.plan_coefficients(plan, w)
    np.testing.assert_array_equal(
        rtopo.w_from_coefficients(plan, diag, coefs), w)
    # churn subset of the support round-trips too
    active = rng.random(k) >= 0.3
    if not active.any():
        active[:] = True
    w_t = np.asarray(topo.reweight_for_active(graph, active))
    diag, coefs = rtopo.plan_coefficients(plan, w_t)
    np.testing.assert_array_equal(
        rtopo.w_from_coefficients(plan, diag, coefs), w_t)


def test_w_from_coefficients_device_matches_host():
    """The jax variant (what `dist.runtime` rebuilds the round's W with for
    the gate recompute) scatters the same matrix as the numpy inverse —
    compared in f32, the dtype the runtime lowers schedules to."""
    graph = topo.connected_cycle(6, 2)
    plan = rtopo.compile_plan(graph)
    w32 = np.asarray(topo.metropolis_weights(graph)).astype(np.float32)
    diag, coefs = rtopo.plan_coefficients(plan, w32)
    host = rtopo.w_from_coefficients(plan, diag, coefs)
    dev = np.asarray(rtopo.w_from_coefficients_device(plan, diag, coefs))
    np.testing.assert_array_equal(dev, host)
    np.testing.assert_array_equal(dev, w32)


def test_w_from_coefficients_validates_shapes():
    plan = rtopo.compile_plan(topo.ring(6))
    diag, coefs = rtopo.plan_coefficients(
        plan, topo.metropolis_weights(topo.ring(6)))
    with pytest.raises(ValueError):
        rtopo.w_from_coefficients(plan, diag[:-1], coefs)
    with pytest.raises(ValueError):
        rtopo.w_from_coefficients(plan, diag, coefs[:-1])


# ---------------------------------------------------------------------------
# block plans: K nodes quotiented onto M < K devices
# ---------------------------------------------------------------------------

def test_block_plan_quotient_structure():
    g = topo.torus_2d(2, 4)  # K=8
    bp = rtopo.compile_block_plan(g, 4)
    assert (bp.num_nodes, bp.num_devices, bp.local_nodes) == (8, 4, 2)
    # node-level support is preserved exactly (intra + inter)
    np.testing.assert_array_equal(bp.support(), g.adjacency)
    assert bp.num_edges == g.adjacency.sum() // 2
    # every intra edge stays inside one block, every inter edge crosses
    for i, j in bp.intra_edges:
        assert i // 2 == j // 2
    for i, j in bp.inter_edges:
        assert i // 2 != j // 2
    # the block coloring is a proper coloring of the collapsed device graph
    blk_edges = [e for cls in bp.block.colors for e in cls]
    coloring.check_coloring(bp.block.colors, blk_edges, 4)
    assert bp.num_colors <= 4  # Delta_block + 1 on 4 devices

    # M == 1: everything is intra, zero communication
    bp1 = rtopo.compile_block_plan(g, 1)
    assert bp1.num_colors == 0 and not bp1.inter_edges
    assert bp1.bytes_per_device_per_step(64) == 0

    with pytest.raises(ValueError, match="divide"):
        rtopo.compile_block_plan(g, 3)


def test_block_plan_collapses_parallel_edges():
    """The quotient multigraph's parallel node-edges ride ONE block
    exchange: complete K_16 on 4 devices needs only 3 colors (K_4's
    chromatic index), not 15."""
    bp = rtopo.compile_block_plan(topo.complete(16), 4)
    assert len(bp.inter_edges) == 96  # 16*15/2 - 4*(4*3/2)
    assert bp.block.num_edges == 6    # collapsed: K_4 on the devices
    assert bp.num_colors == 3         # vs 15 per-node colors
    assert rtopo.compile_plan(topo.complete(16)).num_colors == 15


@given(k=st.integers(2, 16), p=st.floats(0.1, 0.9), seed=st.integers(0, 999),
       drop=st.floats(0.0, 0.5))
@settings(max_examples=40, deadline=None)
def test_block_mix_equals_dense_mix_bitwise(k, p, seed, drop):
    """The block-mode parity contract: for any random sparse
    doubly-stochastic W (and any churn reweighting) and any admissible M,
    block execution == the dense (K, K) matmul BITWISE — each device's
    assembled-buffer dot runs the simulator's own contraction."""
    rng = np.random.default_rng(seed)
    graph = topo.Topology("rand", _random_support(k, p, seed))
    v = rng.standard_normal((k, 7)).astype(np.float32)
    w = topo.metropolis_weights(graph)
    active = rng.random(k) >= drop
    if not active.any():
        active[:] = True
    w_t = topo.reweight_for_active(graph, active)
    for m in (d for d in (1, 2, 4) if k % d == 0):
        bp = rtopo.compile_block_plan(graph, m)
        for w_round in (w, w_t):
            got = np.asarray(rtopo.mix_with_block_plan(bp, w_round, v))
            want = np.asarray(mixing.dense_mix(
                jnp.asarray(w_round, jnp.float32), jnp.asarray(v)))
            np.testing.assert_array_equal(got, want)


def test_block_plan_coverage_validation():
    """Block coverage is wider than the compiled edges — a whole block
    payload moves per exchange — and exactly as wide as what the buffer
    dot executes: intra-block pairs and exchanging-block pairs pass (and
    compute bitwise against dense_mix), while weight between blocks that
    never exchange still fails loudly."""
    bp = rtopo.compile_block_plan(topo.ring(8), 4)  # block graph: 4-cycle
    v = np.arange(32, dtype=np.float32).reshape(8, 4)
    # extra edges that stay inside exchanged blocks or within one block:
    # executable even though they are not compiled graph edges
    w_extra = np.asarray(topo.metropolis_weights(
        topo.connected_cycle(8, 2)))   # +-2 offsets: adjacent-block pairs
    rtopo.check_plan_covers(bp, w_extra)
    np.testing.assert_array_equal(
        np.asarray(rtopo.block_mix_dense(bp, w_extra, v)),
        np.asarray(mixing.dense_mix(jnp.asarray(w_extra, jnp.float32),
                                    jnp.asarray(v))))
    # blocks {0,1} and {4,5} never exchange on the 4-cycle block graph:
    # W[0,4] is genuinely undeliverable and must raise
    w_bad = np.eye(8)
    w_bad[0, 4] = w_bad[4, 0] = 0.5
    with pytest.raises(ValueError, match="outside the compiled plan"):
        rtopo.check_plan_covers(bp, w_bad)
    with pytest.raises(ValueError, match="outside the compiled plan"):
        rtopo.block_mix_dense(bp, w_bad, v)
    # ... but the same entry is intra-block on a 2-device split: covered
    rtopo.check_plan_covers(rtopo.compile_block_plan(topo.ring(8), 2), w_bad)
    # churn subsets stay covered
    act = np.array([1, 1, 0, 1, 1, 1, 0, 1], dtype=bool)
    rtopo.block_mix_dense(bp, topo.reweight_for_active(topo.ring(8), act),
                          np.zeros((8, 4), np.float32))


def test_block_plan_schedule_validates_and_broadcasts():
    g = topo.torus_2d(2, 4)
    bp = rtopo.compile_block_plan(g, 4)  # block graph: a 4-cycle
    rng = np.random.default_rng(0)
    t, k = 5, 8
    w_stack = np.stack([
        topo.reweight_for_active(g, rng.random(k) < 0.8)
        for _ in range(t)]).astype(np.float32)
    ps = rtopo.BlockPlanSchedule.from_w_stack(bp, w_stack)
    assert ps.entries()["plan_w"].shape == (t, k, k)
    # static: broadcast views, validated once
    static = rtopo.BlockPlanSchedule.from_w_stack(
        bp, np.broadcast_to(w_stack[0], (t, k, k)), static=True)
    assert static.w.base is not None
    with pytest.raises(ValueError, match="round-invariant"):
        rtopo.BlockPlanSchedule.from_w_stack(bp, w_stack, static=True)
    # a round with weight between blocks that never exchange fails loudly
    bad = w_stack.copy()
    bad[3] = np.eye(k, dtype=np.float32)
    bad[3, 0, 7] = bad[3, 7, 0] = 0.5  # block 0 <-> block 3: no color
    with pytest.raises(ValueError, match="outside the compiled plan"):
        rtopo.BlockPlanSchedule.from_w_stack(bp, bad)


def test_block_plan_byte_accounting_and_render():
    bp = rtopo.compile_block_plan(topo.complete(16), 4)
    d, item = 64, 4
    ln = bp.local_nodes
    assert bp.bytes_per_link_per_step(d, item) == 2 * ln * d * item
    assert bp.bytes_per_device_per_step(d, item) == \
        bp.num_colors * ln * d * item
    assert bp.total_bytes_per_step(d, item) == \
        bp.block.num_edges * 2 * ln * d * item
    text = bp.render(d=d, itemsize=item)
    assert "colors=3" in text and "intra=24" in text and "inter=96" in text
    assert "dev0<->dev1" in text and "bytes/round" in text
    assert bp.cache_token() != rtopo.compile_block_plan(
        topo.complete(16), 2).cache_token()


# ---------------------------------------------------------------------------
# graph builders
# ---------------------------------------------------------------------------

def test_expander_builder():
    g = rtopo.expander(16, degree=4, seed=1)
    assert rtopo.graphs.is_connected(g.adjacency)
    assert g.adjacency.sum(axis=1).max() <= 4 + 1
    w = topo.metropolis_weights(g)
    assert topo.spectral_gap(w) > topo.spectral_gap(
        topo.metropolis_weights(topo.ring(16)))  # expanders mix faster
    # deterministic in seed
    np.testing.assert_array_equal(
        g.adjacency, rtopo.expander(16, degree=4, seed=1).adjacency)


def test_random_geometric_builder():
    g = rtopo.random_geometric(20, seed=3)
    assert rtopo.graphs.is_connected(g.adjacency)
    assert (g.adjacency == g.adjacency.T).all()
    with pytest.raises(ValueError, match="disconnected"):
        rtopo.random_geometric(20, radius=1e-3, seed=3)


def test_hypercube_builder():
    g = rtopo.hypercube(16)
    assert (g.adjacency.sum(axis=1) == 4).all()
    with pytest.raises(ValueError):
        rtopo.hypercube(12)


def test_registry_builds_all():
    for name in sorted(rtopo.GRAPHS):
        g = rtopo.build(name, 16)
        assert g.num_nodes == 16
        plan = rtopo.compile_plan(g)
        if name != "disconnected":
            assert plan.num_edges > 0
    with pytest.raises(ValueError, match="unknown topology"):
        rtopo.build("moebius", 16)
