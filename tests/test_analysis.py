"""repro.analysis: contract checking, lint passes, and seeded violations.

Mesh-free coverage: ``check_comm`` clauses against synthetic HLO text,
contract constructors against the lowering budget, jaxpr/AST passes on
clean and seeded programs, and the whole-repo AST lint wall. The
mesh-dependent drivers (and the injected-all-gather fixture) run in the
slow subprocess test via ``python -m repro.analysis`` under 8 virtual
devices — the same entry point CI's analysis job runs.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import astlint, contracts, passes, selftest
from repro.core import topology as topo


def _hlo(body: str, sig: str = "(p0: f32[64]) -> f32[64]") -> str:
    return (f"HloModule m\n\nENTRY %main {sig} {{\n"
            + textwrap.dedent(body).rstrip() + "\n}\n")


PERMUTE_HLO = _hlo("""
    %p0 = f32[64] parameter(0)
    ROOT %cp = f32[64] collective-permute(%p0), source_target_pairs={{0,1},{1,0}}
""")

GATHER_HLO = _hlo("""
    %p0 = f32[64] parameter(0)
    ROOT %ag = f32[256] all-gather(%p0), replica_groups={{0,1,2,3}}, dimensions={0}
""", "(p0: f32[64]) -> f32[256]")


# --- check_comm clauses on synthetic HLO -----------------------------------

def test_check_comm_passes_and_returns_report():
    c = contracts.CommContract(name="t", max_collective_permute_bytes=256,
                               max_collective_permute_count=1,
                               require_collective_permute=True)
    report = contracts.check_comm(PERMUTE_HLO, c)
    assert report["collectives"]["collective-permute"] == 256
    assert report["collective_counts"]["collective-permute"] == 1


def test_check_comm_forbidden_kind():
    with pytest.raises(contracts.CommContractViolation,
                       match="forbidden all-gather"):
        contracts.check_comm(GATHER_HLO, contracts.CommContract(name="t"))


def test_check_comm_byte_and_count_caps():
    with pytest.raises(contracts.CommContractViolation, match="bytes/device"):
        contracts.check_comm(PERMUTE_HLO, contracts.CommContract(
            name="t", max_collective_permute_bytes=255))
    with pytest.raises(contracts.CommContractViolation,
                       match="collective-permutes executed"):
        contracts.check_comm(PERMUTE_HLO, contracts.CommContract(
            name="t", max_collective_permute_count=0))


def test_check_comm_require_collective_permute():
    no_coll = _hlo("""
        %p0 = f32[64] parameter(0)
        ROOT %n = f32[64] negate(%p0)
    """)
    with pytest.raises(contracts.CommContractViolation,
                       match="lost its neighbor exchange"):
        contracts.check_comm(no_coll, contracts.CommContract(
            name="t", require_collective_permute=True))


def test_check_comm_all_reduce_allowance_and_floors():
    ar = _hlo("""
        %p0 = f32[64] parameter(0)
        ROOT %ar = f32[64] all-reduce(%p0), replica_groups={{0,1}}
    """)
    ok = contracts.CommContract(
        name="t", forbid=("all-gather",), max_all_reduce_bytes=2 * 64 * 4)
    contracts.check_comm(ar, ok)
    with pytest.raises(contracts.CommContractViolation, match="allowance"):
        contracts.check_comm(ar, contracts.CommContract(
            name="t", forbid=(), max_all_reduce_bytes=64))
    with pytest.raises(contracts.CommContractViolation, match="MUST gather"):
        contracts.check_comm(ar, contracts.gather_contract(
            "t", min_all_gather_bytes=1))
    contracts.check_comm(GATHER_HLO, contracts.gather_contract(
        "t", min_all_gather_bytes=1024, min_total_bytes=1024))
    with pytest.raises(contracts.CommContractViolation, match="total"):
        contracts.check_comm(PERMUTE_HLO, contracts.gather_contract(
            "t", min_total_bytes=10_000))


def test_check_comm_violation_lists_every_clause():
    c = contracts.CommContract(name="multi",
                               max_collective_permute_count=0,
                               min_total_bytes=10_000)
    with pytest.raises(contracts.CommContractViolation) as ei:
        contracts.check_comm(PERMUTE_HLO, c)
    msg = str(ei.value)
    assert "executed > budget" in msg and "total collective bytes" in msg
    assert "[contract multi]" in msg


# --- contract constructors vs the lowering budget --------------------------

def test_plan_contract_matches_comm_budget():
    from repro import topo as rtopo
    from repro.topo.lowering import comm_budget

    plan = rtopo.compile_plan(topo.torus_2d(2, 4))
    d = 48
    budget = comm_budget(plan, d, 4, gossip_steps=2)
    c = plan.contract(d, 4, gossip_steps=2)
    assert c.max_collective_permute_count == budget["collective_permutes"] \
        == 2 * plan.num_colors
    assert c.max_collective_permute_bytes == budget["bytes_per_device"] \
        == 2 * plan.num_colors * d * 4
    assert c.require_collective_permute
    assert c.forbid == contracts.FORBID_NEIGHBOR_ONLY
    assert "collective-permute" in c.describe()


def test_block_plan_contract_within_vizing_budget():
    from repro import topo as rtopo

    k, m, d = 9, 3, 48
    plan = rtopo.compile_block_plan(topo.complete(k), m)
    delta_block = int(np.asarray(
        [row.sum() for row in plan.block.support()]).max())
    c = plan.contract(d)
    assert c.max_collective_permute_count == plan.num_colors \
        <= delta_block + 1
    assert c.max_collective_permute_bytes == \
        plan.num_colors * plan.local_nodes * d * 4


def test_ring_and_certificate_contracts():
    r = contracts.ring_contract(48, conn=2, gossip_steps=3)
    assert r.max_collective_permute_count == 3 * 2 * 2
    assert r.max_collective_permute_bytes == 3 * 2 * 2 * 48 * 4
    cert = contracts.certificate_contract(48)
    assert "all-reduce" not in cert.forbid
    assert cert.max_all_reduce_bytes == (4 * 48 + 64) * 4


# --- jaxpr passes: clean programs stay clean -------------------------------

def test_jaxpr_passes_clean_program():
    def fn(x, w):
        def step(c, _):
            return jnp.tanh(w @ c), None
        from jax import lax
        return lax.scan(step, x, None, length=3)[0]

    findings = passes.run_jaxpr_passes(
        fn, jnp.zeros((8,), jnp.float32), jnp.eye(8, dtype=jnp.float32))
    assert findings == []


def test_dtype_drift_flags_f16_roundtrip():
    def fn(x):
        return x.astype(jnp.float16).astype(jnp.float32)

    closed = jax.make_jaxpr(fn)(jnp.zeros((4,), jnp.float32))
    found = passes.dtype_drift(closed)
    assert any("float16" in f.message for f in found)


def test_donation_pass_accepts_working_donation():
    def fn(x):
        return x * 2.0

    assert passes.donation(fn, (jnp.zeros((8,), jnp.float32),), (0,)) == []


def test_retrace_monitor_clean_on_stable_key():
    from repro.core import executor

    executor.clear_driver_cache()

    def run():
        executor.cached_driver("stable-analysis-key",
                               lambda: (lambda: None))

    assert passes.check_retrace(run) == []
    executor.clear_driver_cache()


def test_walk_eqns_tracks_enclosing_primitives():
    from jax import lax

    def fn(x):
        def step(c, _):
            return jnp.sin(c), None
        return lax.scan(step, x, None, length=2)[0]

    closed = jax.make_jaxpr(fn)(jnp.float32(0.0))
    paths = {eqn.primitive.name: path
             for eqn, path in passes.walk_eqns(closed.jaxpr)}
    assert paths["scan"] == ()
    assert paths["sin"] == ("scan",)


# --- seeded violations: every pass must fire -------------------------------

@pytest.mark.parametrize("name", sorted(selftest.SELFTESTS))
def test_seeded_violation_is_caught(name):
    rows = {r[0]: r for r in selftest.run_selftests(skip_mesh=True)}
    _, caught, detail = rows[name]
    if caught is None:
        pytest.skip(detail)
    assert caught, detail


# --- AST lint wall over the real source tree -------------------------------

def test_repo_source_passes_ast_lints():
    import pathlib

    import repro.analysis as pkg
    src_root = pathlib.Path(pkg.__file__).resolve().parent.parent
    findings = astlint.lint_paths([src_root])
    assert findings == [], "\n".join(str(f) for f in findings)


def test_prng_rule_allows_rebinds_and_branches():
    clean = textwrap.dedent("""
        def sample(key):
            a = jax.random.normal(key, (3,))
            key, sub = jax.random.split(key)
            b = jax.random.normal(key, (3,))
            return a, b

        def branchy(key, flag):
            if flag:
                x = jax.random.normal(key, (3,))
            else:
                x = jax.random.uniform(key, (3,))
            return x

        def two_fns_each_consume_own_param(key):
            return jax.random.normal(key, ())

        def second(key):
            return jax.random.normal(key, ())
    """)
    assert astlint.lint_source(clean) == []


def test_frozen_transform_rule_accepts_frozen():
    ok = textwrap.dedent("""
        @register_scenario("x")
        @dataclasses.dataclass(frozen=True)
        class Fine:
            def apply(self, sched, ctx):
                return None
    """)
    assert astlint.lint_source(ok) == []


# --- the CLI end to end (the CI analysis job) ------------------------------

@pytest.mark.slow
def test_analysis_cli_all_and_selftest_subprocess():
    env = dict(os.environ, PYTHONPATH="src:.")
    env.pop("XLA_FLAGS", None)  # __main__ pins its own 8-device mesh
    out = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--all", "--selftest"],
        env=env, capture_output=True, text=True, timeout=900,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    assert "repro.analysis: OK" in out.stdout
    assert "MISSED" not in out.stdout
    # every registered driver ran on the 8-device mesh (nothing skipped)
    assert "SKIP " not in out.stdout.replace("SKIP selftest", ""), out.stdout
    assert "CAUGHT comm-contract" in out.stdout
