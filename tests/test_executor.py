"""Round-block engine vs the retained per-round loop (bitwise), and the
Gram-cached CD formulation vs the residual one (oracle + Pallas-interpret)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines as bl, problems, topology as topo
from repro.core.cola import ColaConfig, build_env, run_cola
from repro.core.executor import record_flags, run_round_blocks
from repro.core.partition import make_partition
from repro.core.subproblem import (SubproblemSpec, block_gram, cd_solve_all,
                                   gram_pays)
from repro.data import synthetic
from repro.kernels.ops import cd_solve_pallas

K = 8


@pytest.fixture(scope="module")
def ridge():
    x, y, _ = synthetic.regression(200, 64, seed=0)
    return problems.ridge_primal(jnp.asarray(x), jnp.asarray(y), 1e-2)


@pytest.fixture(scope="module")
def lasso_prob():
    x, y, _ = synthetic.regression(200, 64, seed=1, sparsity_solution=0.2)
    return problems.lasso(jnp.asarray(x), jnp.asarray(y), 1e-2)


def _drop(t, rng):
    return rng.random(K) < 0.7


def _budgets(t, rng):
    b = np.full(K, 16)
    b[rng.random(K) < 0.5] = 4
    return b


SCHEDULES = {
    "plain": {},
    "record7": dict(record_every=7),
    "churn": dict(active_schedule=_drop),
    "churn_reset": dict(active_schedule=_drop, leave_mode="reset"),
    "budgets": dict(budget_schedule=_budgets),
    "churn_budgets_reset": dict(active_schedule=_drop,
                                budget_schedule=_budgets, leave_mode="reset"),
}


@pytest.mark.parametrize("case", sorted(SCHEDULES))
def test_block_executor_bitwise_matches_loop(ridge, case):
    """The scan engine must reproduce the make_round loop bit for bit, for
    every schedule feature (churn, heterogeneous budgets, reset-on-leave)."""
    kwargs = SCHEDULES[case]
    loop = run_cola(ridge, topo.ring(K), ColaConfig(kappa=1.0), 31,
                    executor="loop", seed=3, **kwargs)
    block = run_cola(ridge, topo.ring(K), ColaConfig(kappa=1.0), 31,
                     executor="block", block_size=10, seed=3, **kwargs)
    np.testing.assert_array_equal(np.asarray(loop.state.x_parts),
                                  np.asarray(block.state.x_parts))
    np.testing.assert_array_equal(np.asarray(loop.state.v_stack),
                                  np.asarray(block.state.v_stack))
    assert loop.history["round"] == block.history["round"]
    # metric values are computed by the same gap_report, but standalone-jitted
    # in the loop vs fused into the scan — identical up to fusion rounding
    for name in ("primal", "hamiltonian", "dual", "gap",
                 "consensus_violation"):
        np.testing.assert_allclose(loop.history[name], block.history[name],
                                   rtol=1e-5, atol=1e-6, err_msg=name)


def test_block_executor_single_vs_many_blocks(ridge):
    """Block boundaries are invisible: one big block == many small ones."""
    a = run_cola(ridge, topo.ring(K), ColaConfig(kappa=1.0), 24,
                 executor="block", block_size=24)
    b = run_cola(ridge, topo.ring(K), ColaConfig(kappa=1.0), 24,
                 executor="block", block_size=5)
    np.testing.assert_array_equal(np.asarray(a.state.x_parts),
                                  np.asarray(b.state.x_parts))
    np.testing.assert_array_equal(np.asarray(a.state.v_stack),
                                  np.asarray(b.state.v_stack))


def test_record_flags_match_loop_condition():
    rec = record_flags(10, 4)
    assert list(np.nonzero(rec)[0]) == [0, 4, 8, 9]
    assert record_flags(1, 1).tolist() == [True]


def test_zero_rounds_matches_loop(ridge):
    """T=0 returns the initial state and an empty history on both drivers."""
    for ex in ("loop", "block"):
        res = run_cola(ridge, topo.ring(K), ColaConfig(kappa=1.0), 0,
                       executor=ex)
        assert res.history["round"] == []
        assert res.history["primal"] == []
        assert float(jnp.abs(res.state.x_parts).max()) == 0.0


def test_forced_cd_modes_build_matching_env(ridge):
    """cd_mode='gram' must materialize Gram blocks even where the heuristic
    declines; cd_mode='residual' must not pay for them (run_cola wiring)."""
    # wide blocks (n_k > d): heuristic says residual, forcing gram must work
    x, y, _ = synthetic.regression(16, 120, seed=7)
    wide = problems.ridge_primal(jnp.asarray(x), jnp.asarray(y), 1e-2)
    assert not gram_pays(wide.d, make_partition(wide.n, 2).block, 4)
    forced = run_cola(wide, topo.ring(2), ColaConfig(kappa=1.0,
                      cd_mode="gram"), 10, record_every=9)
    auto = run_cola(wide, topo.ring(2), ColaConfig(kappa=1.0), 10,
                    record_every=9)
    np.testing.assert_allclose(np.asarray(forced.state.x_parts),
                               np.asarray(auto.state.x_parts), atol=2e-5)


def test_executor_generic_aux_and_metrics():
    """The engine stacks per-round aux outputs and applies the record mask."""
    from repro.core.metrics import FnRecorder

    def step(s, _ctx, sched_t):
        s = s + sched_t["inc"]
        return s, s * 2.0

    state = jnp.zeros(())
    sched = {"inc": np.arange(1.0, 8.0, dtype=np.float32)}
    rec = np.array([True, False, False, True, False, False, True])
    res = run_round_blocks(step, state, sched,
                           recorder=FnRecorder(("x",),
                                               lambda s: jnp.stack([s])),
                           record_mask=rec, block_size=3)
    totals = np.cumsum(np.arange(1.0, 8.0))
    assert float(res.state) == totals[-1]
    np.testing.assert_allclose(res.aux[:, ...], 2.0 * totals)
    np.testing.assert_allclose(res.metrics[:, 0], totals[rec])
    assert list(res.rounds) == [0, 3, 6]
    assert res.stop_round is None


def test_executor_early_stop_truncates_and_freezes_state():
    """A recorder stop condition turns the rest of the block into no-ops and
    skips later blocks: final state == the full run's state at the stop
    round, metrics truncate at the certifying row."""
    from repro.core.metrics import FnRecorder

    def step(s, _ctx, sched_t):
        return s + sched_t["inc"], None

    sched = {"inc": np.ones((20,), dtype=np.float32)}
    recorder = FnRecorder(("x",), lambda s: jnp.stack([s]),
                          stop=lambda row: row[0] >= 7.0)
    res = run_round_blocks(step, jnp.zeros(()), sched, recorder=recorder,
                           block_size=6)
    # rounds are 1-indexed in value: after round t state == t+1; 7 at t=6
    assert res.stop_round == 6
    assert float(res.state) == 7.0
    assert list(res.rounds) == list(range(7))
    np.testing.assert_allclose(res.metrics[:, 0], np.arange(1.0, 8.0))


def test_make_block_runner_binds_recorder():
    """make_block_runner: the bound runner reproduces run_round_blocks."""
    from repro.core.executor import make_block_runner
    from repro.core.metrics import FnRecorder

    def step(s, _ctx, sched_t):
        return s + sched_t["inc"], None

    run = make_block_runner(step, recorder=FnRecorder(
        ("x",), lambda s: jnp.stack([s]), stop=lambda row: row[0] >= 3.0),
        block_size=4)
    res = run(jnp.zeros(()), {"inc": np.ones((10,), np.float32)})
    assert res.stop_round == 2 and float(res.state) == 3.0


# ---------------------------------------------------------------------------
# Gram-cached CD vs residual CD
# ---------------------------------------------------------------------------

def _cd_inputs(prob, k=4, seed=0):
    part = make_partition(prob.n, k)
    env = build_env(prob, part, with_gram=True)
    key = jax.random.PRNGKey(seed)
    x_parts = 0.1 * jax.random.normal(key, (k, part.block))
    grads = jax.vmap(prob.grad_f)(
        0.3 * jax.random.normal(key, (k, prob.d)))
    spec = SubproblemSpec(sigma_over_tau=k / prob.tau, inv_k=1.0 / k)
    return part, env, x_parts, grads, spec


@pytest.mark.parametrize("name", sorted(problems.PROBLEMS))
def test_gram_oracle_matches_residual_oracle(name):
    x, y, _ = synthetic.regression(64, 36, seed=0)
    xj, yj = jnp.asarray(x), jnp.asarray(y)
    if name.startswith("logistic"):
        yj = jnp.sign(yj) + (jnp.sign(yj) == 0)
    prob = problems.PROBLEMS[name](xj, yj, 1e-2)
    part, env, x_parts, grads, spec = _cd_inputs(prob)
    steps = 2 * part.block
    res = cd_solve_all(prob, spec, env.a_parts, x_parts, grads,
                       env.gp_parts, env.masks, steps)
    grm = cd_solve_all(prob, spec, env.a_parts, x_parts, grads,
                       env.gp_parts, env.masks, steps,
                       gram_parts=env.gram_parts)
    np.testing.assert_allclose(np.asarray(grm), np.asarray(res), atol=2e-5)


def test_gram_oracle_matches_residual_with_budgets(ridge):
    part, env, x_parts, grads, spec = _cd_inputs(ridge)
    steps = 2 * part.block
    budgets = jnp.asarray([steps, 3, 0, steps // 2], jnp.int32)
    res = cd_solve_all(ridge, spec, env.a_parts, x_parts, grads,
                       env.gp_parts, env.masks, steps, step_budgets=budgets)
    grm = cd_solve_all(ridge, spec, env.a_parts, x_parts, grads,
                       env.gp_parts, env.masks, steps, step_budgets=budgets,
                       gram_parts=env.gram_parts)
    np.testing.assert_allclose(np.asarray(grm), np.asarray(res), atol=2e-5)
    # budget 0 still means "no update" on the Gram path
    assert float(jnp.abs(grm[2]).max()) == 0.0


@pytest.mark.parametrize("name", ["ridge_primal", "lasso", "ridge_dual"])
def test_pallas_gram_kernel_matches_oracles(name):
    x, y, _ = synthetic.regression(64, 36, seed=2)
    prob = problems.PROBLEMS[name](jnp.asarray(x), jnp.asarray(y), 1e-2)
    part, env, x_parts, grads, spec = _cd_inputs(prob)
    steps = 2 * part.block
    pl_res = cd_solve_pallas(prob, spec, env.a_parts, x_parts, grads,
                             env.gp_parts, env.masks, steps)
    pl_grm = cd_solve_pallas(prob, spec, env.a_parts, x_parts, grads,
                             env.gp_parts, env.masks, steps, cd_mode="gram",
                             gram_parts=env.gram_parts)
    oracle_grm = cd_solve_all(prob, spec, env.a_parts, x_parts, grads,
                              env.gp_parts, env.masks, steps,
                              gram_parts=env.gram_parts)
    # the Pallas gram kernel is the same recurrence as the jnp gram oracle
    np.testing.assert_allclose(np.asarray(pl_grm), np.asarray(oracle_grm),
                               atol=1e-6)
    # and both agree with the residual formulation to float tolerance
    np.testing.assert_allclose(np.asarray(pl_grm), np.asarray(pl_res),
                               atol=2e-5)


def test_gram_heuristic_boundaries():
    assert gram_pays(d=1000, n_k=64, itemsize=4)       # tall block: cache it
    assert not gram_pays(d=64, n_k=1000, itemsize=4)   # wide block: residual
    assert not gram_pays(d=10 ** 6, n_k=3000, itemsize=4)  # Gram > VMEM
    assert not gram_pays(d=8, n_k=8, itemsize=4)       # no per-step saving


def test_run_cola_gram_vs_residual_full_run(lasso_prob):
    """End-to-end: forcing either CD formulation converges to the same run."""
    grm = run_cola(lasso_prob, topo.ring(K), ColaConfig(kappa=1.0,
                   cd_mode="gram"), 40, record_every=39)
    res = run_cola(lasso_prob, topo.ring(K), ColaConfig(kappa=1.0,
                   cd_mode="residual"), 40, record_every=39)
    np.testing.assert_allclose(grm.history["primal"][-1],
                               res.history["primal"][-1], rtol=1e-4)
    np.testing.assert_allclose(np.asarray(grm.state.x_parts),
                               np.asarray(res.state.x_parts), atol=1e-4)


def test_build_env_gram_follows_heuristic(ridge):
    part = make_partition(ridge.n, K)
    env_auto = build_env(ridge, part)  # n_k=8 << d=200: gram pays
    assert env_auto.gram_parts is not None
    np.testing.assert_allclose(np.asarray(env_auto.gram_parts),
                               np.asarray(block_gram(env_auto.a_parts)),
                               atol=1e-6)
    env_off = build_env(ridge, part, with_gram=False)
    assert env_off.gram_parts is None


# ---------------------------------------------------------------------------
# baselines on the block engine
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def cons():
    x, y, _ = synthetic.regression(200, 32, seed=5)
    return bl.make_consensus_problem(x, y, K, loss="square", reg="l2",
                                     lam=1e-2)


@pytest.mark.parametrize("runner,kwargs", [
    (bl.run_dgd, dict(step=0.3)),
    (bl.run_diging, dict(step=0.3)),
    (bl.run_dadmm, dict(rho=1.0)),
])
def test_baseline_block_matches_loop(cons, runner, kwargs):
    loop = runner(cons, topo.ring(K), rounds=37, record_every=10,
                  executor="loop", **kwargs)
    block = runner(cons, topo.ring(K), rounds=37, record_every=10,
                   executor="block", block_size=16, **kwargs)
    np.testing.assert_array_equal(np.asarray(loop.w_stack),
                                  np.asarray(block.w_stack))
    assert loop.history["round"] == block.history["round"]
    np.testing.assert_allclose(loop.history["objective"],
                               block.history["objective"], rtol=1e-6)
    np.testing.assert_allclose(loop.history["consensus"],
                               block.history["consensus"],
                               rtol=1e-5, atol=1e-7)


# ---------------------------------------------------------------------------
# gossip-DP on the block engine
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_gossip_block_runner_matches_step_loop():
    from repro.configs.base import get_config, smoke_variant
    from repro.optim import gossip as gsp
    from repro.train.data import TokenBatches
    from repro.train.steps import TrainHParams, init_train_state, \
        make_train_step

    cfg = smoke_variant(get_config("xlstm_125m"))
    hp = TrainHParams(lr=1e-3)
    state0 = init_train_state(cfg, jax.random.PRNGKey(0), hp)
    local = make_train_step(cfg, hp)
    pipe = TokenBatches(cfg.vocab_size, 2, 16, corpus_tokens=1 << 12)
    k, rounds = 4, 6
    gcfg = gsp.GossipConfig(num_nodes=k)
    w = jnp.asarray(gcfg.weights(), jnp.float32)
    act = jnp.ones((k,), jnp.float32)

    def stacked(step):
        return jax.tree.map(
            jnp.asarray, jax.tree.map(lambda *xs: np.stack(xs),
                                      *[pipe(step, shard=j)
                                        for j in range(k)]))

    batches = [stacked(t) for t in range(rounds)]
    states = gsp.replicate_state(state0, k)
    step = gsp.make_gossip_step(local, gcfg)
    losses = []
    for t in range(rounds):
        states, m = step(states, batches[t], w, act)
        losses.append(float(jnp.mean(m["loss"])))

    runner = gsp.make_gossip_block_runner(local, gcfg)
    states2 = gsp.replicate_state(state0, k)
    bat_stack = jax.tree.map(lambda *xs: jnp.stack(xs), *batches)
    states2, metrics = runner(
        states2, bat_stack, jnp.broadcast_to(w, (rounds, k, k)),
        jnp.broadcast_to(act, (rounds, k)), gsp.mix_schedule(rounds, 1),
        block_size=4)
    losses2 = np.asarray(metrics["loss"]).mean(axis=1)
    np.testing.assert_allclose(losses, losses2, rtol=1e-5)
    for a, b in zip(jax.tree.leaves(states.params),
                    jax.tree.leaves(states2.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-4)


def test_gossip_mix_schedule():
    from repro.optim.gossip import mix_schedule
    np.testing.assert_array_equal(mix_schedule(6, 2),
                                  [False, True, False, True, False, True])
    assert mix_schedule(4, 1).all()
