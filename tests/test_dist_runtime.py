"""shard_map CoLA runtime == single-host simulator, bit-for-bit per round.

Runs in a subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8
so the main test process keeps the single real CPU device (per the dry-run
isolation rule)."""
import os
import subprocess
import sys
import textwrap

import pytest

# the multi-host shard_map runtime is a roadmap item (see ROADMAP.md "Open
# items"); skip until the repro.dist package lands
pytest.importorskip("repro.dist", reason="repro.dist runtime not built yet")

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.data import synthetic
    from repro.core import problems, topology as topo
    from repro.core.cola import ColaConfig, run_cola
    from repro.dist.runtime import run_dist_cola

    x, y, w = synthetic.regression(160, 64, seed=0)
    mesh = jax.make_mesh((8,), ("data",))
    graph = topo.ring(8)
    for pname, lam in (("ridge_primal", 1e-2), ("lasso", 1e-3)):
        prob = problems.PROBLEMS[pname](jnp.asarray(x), jnp.asarray(y), lam)
        for cfg in (ColaConfig(kappa=1.0), ColaConfig(kappa=0.5, gossip_steps=2)):
            sim = run_cola(prob, graph, cfg, rounds=8)
            for comm in ("dense", "ring"):
                st, hist = run_dist_cola(prob, graph, cfg, mesh, rounds=8,
                                         comm=comm)
                assert np.allclose(hist["primal"][-1],
                                   sim.history["primal"][-1], rtol=1e-5), (
                    pname, comm, hist["primal"][-1], sim.history["primal"][-1])
                assert np.allclose(hist["gap"][-1], sim.history["gap"][-1],
                                   rtol=1e-4, atol=1e-5)
    print("DIST_OK")
""")


@pytest.mark.slow
def test_shardmap_runtime_matches_simulator():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert "DIST_OK" in out.stdout, out.stdout + "\n" + out.stderr
