"""shard_map CoLA runtime == single-host simulator, bit-for-bit per round.

Runs in a subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8
so the main test process keeps the single real CPU device (per the dry-run
isolation rule)."""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.data import synthetic
    from repro.core import problems, topology as topo
    from repro.core.cola import ColaConfig, run_cola
    from repro.dist.runtime import run_dist_cola

    x, y, w = synthetic.regression(160, 64, seed=0)
    mesh = jax.make_mesh((8,), ("data",))
    graph = topo.ring(8)
    for pname, lam in (("ridge_primal", 1e-2), ("lasso", 1e-3)):
        prob = problems.PROBLEMS[pname](jnp.asarray(x), jnp.asarray(y), lam)
        for cfg in (ColaConfig(kappa=1.0), ColaConfig(kappa=0.5, gossip_steps=2)):
            sim = run_cola(prob, graph, cfg, rounds=8)
            for comm in ("dense", "ring"):
                hist = run_dist_cola(prob, graph, cfg, mesh, rounds=8,
                                     comm=comm).history
                assert np.allclose(hist["primal"][-1],
                                   sim.history["primal"][-1], rtol=1e-5), (
                    pname, comm, hist["primal"][-1], sim.history["primal"][-1])
                assert np.allclose(hist["gap"][-1], sim.history["gap"][-1],
                                   rtol=1e-4, atol=1e-5)
    print("DIST_OK")
""")


def _run_isolated(script: str, token: str) -> None:
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=900,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert token in out.stdout, out.stdout + "\n" + out.stderr


@pytest.mark.slow
def test_shardmap_runtime_matches_simulator():
    _run_isolated(SCRIPT, "DIST_OK")


# the mesh/ppermute gossip-DP path on the shared round-block engine: block
# runner == per-round shard_map driver (same contract the dense vmap path
# pins in test_executor.test_gossip_block_runner_matches_step_loop)
GOSSIP_MESH_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs.base import get_config, smoke_variant
    from repro.optim import gossip as gsp
    from repro.train.data import TokenBatches
    from repro.train.steps import TrainHParams, init_train_state, \\
        make_train_step

    cfg = smoke_variant(get_config("xlstm_125m"))
    hp = TrainHParams(lr=1e-3)
    state0 = init_train_state(cfg, jax.random.PRNGKey(0), hp)
    local = make_train_step(cfg, hp)
    pipe = TokenBatches(cfg.vocab_size, 2, 16, corpus_tokens=1 << 12)
    k, rounds = 4, 6
    mesh = jax.make_mesh((4,), ("nodes",))
    gcfg = gsp.GossipConfig(num_nodes=k)
    w = jnp.asarray(gcfg.weights(), jnp.float32)
    act = jnp.ones((k,), jnp.float32)

    def stacked(step):
        return jax.tree.map(jnp.asarray,
                            jax.tree.map(lambda *xs: np.stack(xs),
                                         *[pipe(step, shard=j)
                                           for j in range(k)]))
    batches = [stacked(t) for t in range(rounds)]
    sh = NamedSharding(mesh, P("nodes"))
    put = lambda tree: jax.tree.map(lambda x: jax.device_put(x, sh), tree)

    states = put(gsp.replicate_state(state0, k))
    step = gsp.make_gossip_step(local, gcfg, mesh=mesh, axis="nodes", conn=1)
    losses = []
    for t in range(rounds):
        states, m = step(states, batches[t], w, act)
        losses.append(float(jnp.mean(m["loss"])))

    runner = gsp.make_gossip_block_runner(local, gcfg, mesh=mesh,
                                          axis="nodes", conn=1)
    states2 = put(gsp.replicate_state(state0, k))
    bat_stack = jax.tree.map(lambda *xs: jnp.stack(xs), *batches)
    states2, metrics = runner(
        states2, bat_stack, jnp.broadcast_to(w, (rounds, k, k)),
        jnp.broadcast_to(act, (rounds, k)), gsp.mix_schedule(rounds, 1),
        block_size=3)
    losses2 = np.asarray(metrics["loss"]).mean(axis=1)
    np.testing.assert_allclose(losses, losses2, rtol=1e-5)
    for a, b in zip(jax.tree.leaves(states.params),
                    jax.tree.leaves(states2.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-4)
    print("GOSSIP_MESH_BLOCK_OK")
""")


@pytest.mark.slow
def test_gossip_mesh_block_runner_matches_step_loop():
    _run_isolated(GOSSIP_MESH_SCRIPT, "GOSSIP_MESH_BLOCK_OK")
