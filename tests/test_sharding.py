"""Sharding rules: every emitted PartitionSpec divides its dim on the
production mesh sizes — for all 10 archs (this is what makes the dry-run's
.lower() accept the in_shardings)."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import ARCHS, SHAPES, get_config
from repro.dist import sharding as shd
from repro.launch.specs import cache_specs, params_specs

SIZES = {"data": 16, "model": 16, "pod": 2}
AXES = shd.MeshAxes()


def _check_divisible(shape_tree, spec_tree, tag):
    def check(path, leaf, spec):
        assert isinstance(spec, P)
        for dim, axis in zip(leaf.shape, tuple(spec)):
            if axis is None:
                continue
            axes = axis if isinstance(axis, tuple) else (axis,)
            total = 1
            for a in axes:
                total *= SIZES[a]
            assert dim % total == 0, (tag, jax.tree_util.keystr(path),
                                      leaf.shape, spec)
    jax.tree_util.tree_map_with_path(
        check, shape_tree, spec_tree,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


@pytest.mark.parametrize("arch", ARCHS)
def test_param_specs_divisible(arch):
    cfg = get_config(arch)
    shapes = params_specs(cfg)
    specs = shd.param_pspecs(shapes, AXES, SIZES)
    _check_divisible(shapes, specs, arch)


@pytest.mark.parametrize("arch", ARCHS)
def test_cache_specs_divisible(arch):
    cfg = get_config(arch)
    for shape in SHAPES.values():
        if shape.kind != "decode":
            continue
        if shape.name == "long_500k" and not cfg.sub_quadratic:
            continue
        cache = cache_specs(cfg, shape.global_batch, shape.seq_len)
        specs = shd.cache_pspecs(cfg, cache, shape.global_batch, AXES, SIZES)
        _check_divisible(cache, specs, f"{arch}:{shape.name}")


@pytest.mark.parametrize("arch", ["qwen3_4b", "llama4_maverick_400b"])
def test_large_weights_are_sharded(arch):
    """FSDP+TP actually triggers: at least half the parameter bytes sit on
    leaves with a non-trivial spec."""
    cfg = get_config(arch)
    shapes = params_specs(cfg)
    specs = shd.param_pspecs(shapes, AXES, SIZES)
    total = sharded = 0
    for leaf, spec in zip(jax.tree.leaves(shapes), jax.tree.leaves(
            specs, is_leaf=lambda x: isinstance(x, P))):
        nbytes = leaf.size * leaf.dtype.itemsize
        total += nbytes
        if any(a is not None for a in tuple(spec)):
            sharded += nbytes
    assert sharded > 0.9 * total, (arch, sharded / total)
