"""The quantized gossip wire: codec properties, error feedback, parity.

Four layers, mirroring the wire's contract:

* **Codec properties** (``repro.core.quant``, hypothesis-style): the
  quantize-dequantize roundtrip error is bounded by the grid step (absmax/127
  per row for int8; a RELATIVE ulp bound for fp8, whose grid is
  power-of-two-aligned), scales are exactly absmax/qmax, payload bits are
  invariant to power-of-two rescaling, stochastic rounding is unbiased in
  expectation and keyed-deterministic, and per-node keys depend on GLOBAL
  node ids only — the property that makes the wire bits shard-invariant.
* **Error feedback**: the residual update telescopes (sum of what the
  network saw equals the sum of what the nodes meant to send, up to the
  final residual) and the residual itself stays grid-step bounded.
* **Executor + runtime parity**: ``wire=int8/fp8`` runs match between the
  simulator and ``run_dist_cola`` BITWISE on 1-device meshes (both comm
  modes, static + churn) and on 2/4-device block meshes (slow subprocess
  pin); the software-pipelined executor is a bitwise no-op on the results.
* **The acceptance pin**: on the fig3 ring and torus configs, EF int8/fp8
  reaches the eps-certified stop within 2x the fp32 round count, while the
  SAME wire without EF sits on its quantization noise floor ABOVE eps for
  the whole budget — the observable fact that the residual carry, not the
  codec, is what preserves convergence.

Config corners the wire rejects (attacks / robust / mixed gradients /
pipeline-on-fp32 / pipeline-under-reset) and the gossip-SGD + DP wire
(stateless pytree codec, clip -> quantize -> re-clip order) are pinned at
the bottom.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro import attack
from repro.core import problems, quant, topology as topo
from repro.core.cola import ColaConfig, run_cola
from repro.data import synthetic
from repro.dist.runtime import run_dist_cola
from repro.optim import privacy
from repro.optim.gossip import GossipConfig, _param_mixer, mix_pytree

K = 8


def _rows(seed: int, k: int = 4, d: int = 33, scale: float = 1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((k, d)) * scale, jnp.float32)


# ---------------------------------------------------------------------------
# codec properties
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), d=st.integers(1, 64),
       mag=st.floats(-6.0, 6.0))
def test_int8_roundtrip_error_bound(seed, d, mag):
    """Round-to-nearest: |deq - x| <= scale/2 per element, scale = absmax/127
    per row exactly."""
    x = _rows(seed, k=3, d=d, scale=10.0 ** mag)
    q, s = quant.quantize(x, "int8")
    absmax = np.max(np.abs(np.asarray(x)), axis=-1, keepdims=True)
    want_scale = np.where(absmax > 0,
                          absmax * np.float32(1.0 / 127.0), np.float32(1.0))
    np.testing.assert_array_equal(np.asarray(s), want_scale)
    assert q.dtype == jnp.int8
    err = np.abs(np.asarray(quant.dequantize(q, s)) - np.asarray(x))
    assert np.all(err <= 0.5 * np.asarray(s) * (1 + 1e-6))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), d=st.integers(1, 64))
def test_int8_stochastic_roundtrip_error_bound(seed, d):
    """Stochastic rounding moves at most ONE grid step: |deq - x| <= scale."""
    x = _rows(seed, k=3, d=d)
    q, s = quant.quantize(x, "int8", key=jax.random.PRNGKey(seed))
    err = np.abs(np.asarray(quant.dequantize(q, s)) - np.asarray(x))
    assert np.all(err <= np.asarray(s) * (1 + 1e-6))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), wire=st.sampled_from(["fp8", "fp8_e5m2"]),
       stochastic=st.sampled_from([False, True]))
def test_fp8_roundtrip_relative_ulp_bound(seed, wire, stochastic):
    """The fp8 grid is power-of-two-aligned, so the error bound is RELATIVE:
    one ulp = |x| * 2^-mant at each element (plus the 2^-24 subnormal floor
    of the stochastic grid), NOT absmax/qmax."""
    mant = {"fp8": 3, "fp8_e5m2": 2}[wire]
    x = _rows(seed, k=3, d=48)
    key = jax.random.PRNGKey(seed) if stochastic else None
    q, s = quant.quantize(x, wire, key=key)
    assert q.dtype == quant.wire_dtype(wire)
    deq = np.asarray(quant.dequantize(q, s))
    # RN error is ulp/2, SR error is one full ulp; the grid floor for
    # near-zero elements is scale * 2^(-24 - mant)
    factor = 2.0 ** -mant if stochastic else 2.0 ** -(mant + 1)
    bound = (np.abs(np.asarray(x)) * factor * (1 + 1e-5)
             + np.asarray(s) * 2.0 ** (-24 + 1))
    assert np.all(np.abs(deq - np.asarray(x)) <= bound)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000),
       wire=st.sampled_from(["int8", "fp8", "fp8_e5m2"]),
       log2c=st.integers(-8, 8), stochastic=st.sampled_from([False, True]))
def test_scale_invariance_power_of_two(seed, wire, log2c, stochastic):
    """Rescaling the input by 2^c leaves the payload BITS untouched and
    multiplies the scale sidecar exactly — absmax scaling is exact in fp32
    for power-of-two factors, so x/scale is bitwise invariant."""
    x = _rows(seed)
    c = np.float32(2.0 ** log2c)
    key = jax.random.PRNGKey(seed) if stochastic else None
    q0, s0 = quant.quantize(x, wire, key=key)
    q1, s1 = quant.quantize(x * c, wire, key=key)
    np.testing.assert_array_equal(
        np.asarray(q0).view(np.uint8), np.asarray(q1).view(np.uint8))
    np.testing.assert_array_equal(np.asarray(s0) * c, np.asarray(s1))


@pytest.mark.parametrize("wire", ["int8", "fp8"])
def test_stochastic_rounding_unbiased(wire):
    """E[dequantize(quantize(x, key))] = x: the empirical mean over many
    keys lands within 5 sigma of x (sigma <= grid_step / (2 sqrt(n)))."""
    x = _rows(7, k=1, d=16)
    n = 4000
    keys = jax.vmap(jax.random.PRNGKey)(jnp.arange(n, dtype=jnp.uint32))

    def deq(key):
        q, s = quant.quantize(x, wire, key=key)
        return quant.dequantize(q, s)

    mean = np.asarray(jnp.mean(jax.vmap(deq)(keys), axis=0))
    if wire == "int8":
        step = np.broadcast_to(
            np.max(np.abs(np.asarray(x)), -1, keepdims=True) / 127.0, x.shape)
    else:
        step = np.abs(np.asarray(x)) * 2.0 ** -3 + 1e-6
    assert np.all(np.abs(mean - np.asarray(x)) <= 5.0 * step / (2 * n ** 0.5))


def test_keyed_determinism_and_sensitivity():
    x = _rows(11)
    k1, k2 = jax.random.PRNGKey(1), jax.random.PRNGKey(2)
    for wire in ("int8", "fp8"):
        qa, sa = quant.quantize(x, wire, key=k1)
        qb, sb = quant.quantize(x, wire, key=k1)
        np.testing.assert_array_equal(np.asarray(qa).view(np.uint8),
                                      np.asarray(qb).view(np.uint8))
        np.testing.assert_array_equal(np.asarray(sa), np.asarray(sb))
        qc, _ = quant.quantize(x, wire, key=k2)
        assert not np.array_equal(np.asarray(qa).view(np.uint8),
                                  np.asarray(qc).view(np.uint8))


def test_node_keys_global_row_ids_shard_invariant():
    """A (K, d) stack quantized whole and a 2-row shard quantized with its
    GLOBAL node ids produce the same wire bits for those rows — the property
    that makes sim / per-node plan / block shards bitwise interchangeable."""
    v = _rows(3, k=K, d=24)
    key = quant.step_key(quant.round_keys(0, 1)[0])
    q_full, s_full = quant.quantize_rows(v, "int8", key,
                                         node_ids=jnp.arange(K))
    shard = jnp.asarray([3, 5])
    q_sh, s_sh = quant.quantize_rows(v[shard], "int8", key, node_ids=shard)
    np.testing.assert_array_equal(np.asarray(q_full)[np.asarray(shard)],
                                  np.asarray(q_sh))
    np.testing.assert_array_equal(np.asarray(s_full)[np.asarray(shard)],
                                  np.asarray(s_sh))


def test_wire_names_bytes_and_rejections():
    assert quant.canonical_wire(None) == "fp32"
    assert not quant.is_quantized("fp32") and quant.is_quantized("int8")
    with pytest.raises(ValueError, match="wire="):
        quant.canonical_wire("int4")
    with pytest.raises(ValueError, match="no quantization grid"):
        quant.wire_qmax("fp32")
    assert quant.wire_itemsize("fp32") == 4
    for w in ("int8", "fp8", "fp8_e5m2"):
        assert quant.wire_itemsize(w) == 1
    d, rows = 100, 2
    assert quant.payload_bytes(d, "fp32", rows) == rows * d * 4
    assert quant.payload_bytes(d, "int8", rows) == rows * (d + 4)
    # fp32 wire view is the identity (no codec, EF untouched)
    v = _rows(0)
    out, ef = quant.wire_view(v, None, "fp32")
    assert out is v and ef is None
    assert quant.ef_init(v, "fp32") is None


# ---------------------------------------------------------------------------
# error feedback
# ---------------------------------------------------------------------------

def test_ef_telescopes_and_residual_bounded():
    """EF sends Q(v + ef) and keeps ef' = (v + ef) - deq, so over T rounds
    sum(deq_t) = sum(v_t) - ef_T: the network's view of the traffic differs
    from the intended traffic by ONE residual, not T accumulated errors —
    and that residual is grid-step bounded at every round."""
    rng = np.random.default_rng(5)
    ef = quant.ef_init(jnp.zeros((3, 20)), "int8")
    total_v = np.zeros((3, 20), np.float64)
    total_deq = np.zeros((3, 20), np.float64)
    for t in range(30):
        v = jnp.asarray(rng.standard_normal((3, 20)), jnp.float32)
        key = quant.step_key(quant.round_keys(0, 31)[t])
        q, s, deq, ef = quant.encode(v, "int8", key, None, ef)
        total_v += np.asarray(v, np.float64)
        total_deq += np.asarray(deq, np.float64)
        # stochastic rounding moves <= 1 step, so |ef| <= 2 * scale
        assert np.all(np.abs(np.asarray(ef)) <= 2.0 * np.asarray(s) + 1e-6)
    np.testing.assert_allclose(total_deq + np.asarray(ef), total_v,
                               rtol=0, atol=1e-4)


# ---------------------------------------------------------------------------
# executor <-> dist runtime parity (1 device in-process; 2/4 dev subprocess)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def ridge():
    x, y, _ = synthetic.regression(150, 48, seed=4)
    return problems.ridge_primal(jnp.asarray(x), jnp.asarray(y), 1e-2)


@pytest.fixture(scope="module")
def mesh1():
    return jax.make_mesh((1,), ("data",))


def _drop(t, rng):
    return rng.random(K) < 0.7


def _assert_state_parity(a, b, case, bitwise=True):
    eq = (np.testing.assert_array_equal if bitwise
          else lambda x, y, err_msg: np.testing.assert_allclose(
              x, y, rtol=1e-5, atol=1e-6, err_msg=err_msg))
    eq(np.asarray(a.state.x_parts), np.asarray(b.state.x_parts),
       err_msg=case)
    eq(np.asarray(a.state.v_stack), np.asarray(b.state.v_stack),
       err_msg=case)
    assert a.history["round"] == b.history["round"]
    for name in ("primal", "dual", "gap"):
        np.testing.assert_allclose(a.history[name], b.history[name],
                                   rtol=1e-5, atol=1e-6,
                                   err_msg=f"{case}:{name}")


@pytest.mark.parametrize("comm", ["plan", "dense"])
@pytest.mark.parametrize("wire", ["int8", "fp8"])
def test_quant_dist_bitwise_matches_sim_1dev(ridge, mesh1, wire, comm):
    """wire=int8/fp8 through the real shard_map runtime on a 1-device mesh
    reproduces the simulator bit for bit — the codec draws are a function
    of (seed, round, step, color, node) alone, static AND under churn."""
    cfg = ColaConfig(kappa=1.0, wire=wire)
    for kwargs in ({}, dict(active_schedule=_drop)):
        case = f"{wire}:{comm}:{sorted(kwargs)}"
        sim = run_cola(ridge, topo.torus_2d(2, K // 2), cfg, 25,
                       record_every=6, seed=3, **kwargs)
        dist = run_dist_cola(ridge, topo.torus_2d(2, K // 2), cfg, mesh1, 25,
                             comm=comm, record_every=6, seed=3, **kwargs)
        _assert_state_parity(sim, dist, case)


def test_wire_kwarg_overrides_cfg(ridge, mesh1):
    a = run_dist_cola(ridge, topo.ring(K), ColaConfig(kappa=1.0, wire="int8"),
                      mesh1, 12, comm="dense", record_every=6)
    b = run_dist_cola(ridge, topo.ring(K), ColaConfig(kappa=1.0),
                      mesh1, 12, comm="dense", record_every=6, wire="int8")
    _assert_state_parity(a, b, "wire= kwarg")


def test_pipeline_is_bitwise_noop(ridge, mesh1):
    """Software pipelining only REORDERS the encode/exchange schedule (round
    t+1's payload is encoded with round t+1's key, just one round early), so
    results are bitwise identical — sim and dist."""
    for wire in ("int8", "fp8"):
        base = ColaConfig(kappa=1.0, wire=wire)
        piped = ColaConfig(kappa=1.0, wire=wire, pipeline=True)
        sim = run_cola(ridge, topo.torus_2d(2, K // 2), base, 25,
                       record_every=6)
        sim_p = run_cola(ridge, topo.torus_2d(2, K // 2), piped, 25,
                         record_every=6)
        _assert_state_parity(sim, sim_p, f"sim pipeline {wire}")
        dist_p = run_dist_cola(ridge, topo.torus_2d(2, K // 2), piped, mesh1,
                               25, comm="plan", record_every=6)
        _assert_state_parity(sim, dist_p, f"dist pipeline {wire}")


QUANT_BLOCK_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro.data import synthetic
    from repro.core import problems, topology as topo
    from repro.core.cola import ColaConfig, run_cola
    from repro.dist.runtime import run_dist_cola

    assert jax.device_count() == 4
    K = 8
    graph = topo.torus_2d(2, 4)
    x, y, _ = synthetic.regression(150, 48, seed=4)
    prob = problems.ridge_primal(jnp.asarray(x), jnp.asarray(y), 1e-2)

    def churn(t, rng):
        return rng.random(K) < 0.7

    for wire in ("int8", "fp8"):
        cfg = ColaConfig(kappa=1.0, wire=wire)
        for kwargs in ({}, dict(active_schedule=churn)):
            sim = run_cola(prob, graph, cfg, 25, record_every=6, seed=3,
                           **kwargs)
            for m in (2, 4):
                mesh = jax.make_mesh((m,), ("data",))
                dist = run_dist_cola(prob, graph, cfg, mesh, 25, comm="plan",
                                     record_every=6, seed=3, **kwargs)
                np.testing.assert_array_equal(
                    np.asarray(sim.state.x_parts),
                    np.asarray(dist.state.x_parts))
                np.testing.assert_array_equal(
                    np.asarray(sim.state.v_stack),
                    np.asarray(dist.state.v_stack))

    # the pipelined executor is a bitwise no-op on a REAL 4-device mesh too
    mesh = jax.make_mesh((4,), ("data",))
    base = run_dist_cola(prob, graph, ColaConfig(kappa=1.0, wire="int8"),
                         mesh, 25, comm="plan", record_every=6)
    piped = run_dist_cola(prob, graph,
                          ColaConfig(kappa=1.0, wire="int8", pipeline=True),
                          mesh, 25, comm="plan", record_every=6)
    np.testing.assert_array_equal(np.asarray(base.state.v_stack),
                                  np.asarray(piped.state.v_stack))
    print("QUANT_PARITY_OK")
""")


@pytest.mark.slow
def test_quant_block_plan_4dev_subprocess():
    """wire=int8/fp8 sim<->dist bitwise parity on REAL 2/4-device meshes
    (the in-process suite above runs on whatever the session has)."""
    env = dict(os.environ, PYTHONPATH="src:.")
    out = subprocess.run([sys.executable, "-c", QUANT_BLOCK_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert "QUANT_PARITY_OK" in out.stdout, out.stdout + "\n" + out.stderr


# ---------------------------------------------------------------------------
# robust= x wire= on the dist runtime: the gate judges dequantized rows
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("comm", ["plan", "dense"])
@pytest.mark.parametrize("robust", ["trim", "median", "clip"])
def test_robust_quant_dist_matches_sim_1dev(ridge, mesh1, robust, comm):
    """robust= mixing composes with a quantized wire on the shard_map
    runtime (formerly rejected): per-neighborhood decode buffers feed the
    outlier gate the DEQUANTIZED rows, so dist reproduces the simulator.
    trim/median are bitwise; clip's scale reduction accumulates color-major
    on the plan path (allclose at ~1 ulp there, bitwise on dense)."""
    cfg = ColaConfig(kappa=1.0, wire="int8", robust=robust)
    sim = run_cola(ridge, topo.torus_2d(2, K // 2), cfg, 25,
                   record_every=6, seed=3)
    dist = run_dist_cola(ridge, topo.torus_2d(2, K // 2), cfg, mesh1, 25,
                         comm=comm, record_every=6, seed=3)
    _assert_state_parity(sim, dist, f"robust:{robust}:{comm}",
                         bitwise=not (robust == "clip" and comm == "plan"))


ROBUST_WIRE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    os.environ["REPRO_RUNS_DIR"] = "off"
    import jax, jax.numpy as jnp, numpy as np
    from repro import attack
    from repro.data import synthetic
    from repro.core import problems, topology as topo
    from repro.core.cola import ColaConfig, run_cola
    from repro.dist.runtime import run_dist_cola

    assert jax.device_count() == 4
    K = 8
    x, y, _ = synthetic.regression(150, 48, seed=4)
    prob = problems.ridge_primal(jnp.asarray(x), jnp.asarray(y), 1e-2)
    graph = topo.torus_2d(2, 4)
    mesh = jax.make_mesh((4,), ("data",))

    for robust in ("trim", "median", "clip"):
        cfg = ColaConfig(kappa=1.0, wire="int8", robust=robust)
        sim = run_cola(prob, graph, cfg, 25, record_every=6, seed=3)
        for comm in ("plan", "dense"):
            dist = run_dist_cola(prob, graph, cfg, mesh, 25, comm=comm,
                                 record_every=6, seed=3)
            if robust == "clip" and comm == "plan":
                np.testing.assert_allclose(
                    np.asarray(sim.state.v_stack),
                    np.asarray(dist.state.v_stack),
                    rtol=1e-5, atol=1e-6, err_msg=f"{robust}:{comm}")
            else:
                np.testing.assert_array_equal(
                    np.asarray(sim.state.v_stack),
                    np.asarray(dist.state.v_stack),
                    err_msg=f"{robust}:{comm}")

    # gate-split pin: a defended run under a seeded sign-flip attacker
    # counts the SAME per-sender rejections in sim and dist, and every
    # rejection lands on the dishonest column. fp32 wire: attacks= with a
    # quantized wire is still rejected on the dist runtime — the pin
    # targets the per-node CommPlan telemetry path, which reconstructs the
    # round's W from plan_diag/plan_coefs for the gate recompute
    graph = topo.complete(8)
    cfg = ColaConfig(kappa=1.0, robust="trim", telemetry=True)
    atk = [attack.Byzantine(nodes=(2,), mode="sign_flip", scale=3.0)]
    sim = run_cola(prob, graph, cfg, 10, attacks=atk)
    dist = run_dist_cola(prob, graph, cfg, mesh, 10, comm="plan",
                         attacks=atk)
    ts, td = sim.history["telemetry"], dist.history["telemetry"]
    assert ts["gate_rejections"] == td["gate_rejections"], (ts, td)
    assert td["gate_total"] > 0
    assert td["gate_dishonest"] == td["gate_total"]
    assert td["gate_honest"] == 0
    print("ROBUST_WIRE_OK")
""")


@pytest.mark.slow
def test_robust_wire_4dev_subprocess():
    """robust= x wire= sim<->dist parity AND the telemetry gate-split pin
    on a real 4-device mesh (the per-node CommPlan path reconstructs the
    round's W from plan_diag/plan_coefs for the gate recompute)."""
    env = dict(os.environ, PYTHONPATH="src:.")
    out = subprocess.run([sys.executable, "-c", ROBUST_WIRE_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert "ROBUST_WIRE_OK" in out.stdout, out.stdout + "\n" + out.stderr


# ---------------------------------------------------------------------------
# the acceptance pin: EF reaches the eps-certified stop, no-EF stalls
# ---------------------------------------------------------------------------

#: (graph builder, rounds budget, {wire: eps}) — eps sits between the EF
#: noise floor (EF runs certify) and the no-EF floor (no-EF runs never do);
#: measured floors on this fixture leave >= 2x margin on both sides
_PIN_CONFIGS = (
    ("ring", lambda: topo.ring(16), 800, {"int8": 30.0, "fp8": 100.0}),
    ("torus", lambda: topo.torus_2d(4, 4), 520, {"int8": 8.0, "fp8": 50.0}),
)


def _first_crossing(history, eps):
    gaps = np.asarray(history["gap"])
    hit = np.nonzero(gaps <= eps)[0]
    return None if hit.size == 0 else int(history["round"][hit[0]])


@pytest.mark.slow
@pytest.mark.parametrize("name,build,rounds,eps_by_wire", _PIN_CONFIGS,
                         ids=[c[0] for c in _PIN_CONFIGS])
def test_ef_certifies_within_2x_fp32_and_no_ef_stalls(name, build, rounds,
                                                      eps_by_wire):
    """The fig3 ring/torus acceptance pin: for each quantized wire there is
    an eps that (a) fp32 certifies, (b) EF certifies within 2x the fp32
    round count, and (c) the SAME wire without EF never reaches in the whole
    budget — its gap noise floor sits above eps. Deterministic: the SR draws
    are a pure function of (seed, round, step, node)."""
    from benchmarks.common import make_ridge  # the fig3 fixture

    prob, _ = make_ridge(lam=1e-5, seed=2)
    graph = build()

    def gap_history(wire, ef):
        cfg = ColaConfig(kappa=1.0, wire=wire, error_feedback=ef)
        return run_cola(prob, graph, cfg, rounds, record_every=2,
                        recorder="gap").history

    h_fp32 = gap_history("fp32", True)
    for wire, eps in eps_by_wire.items():
        r_fp32 = _first_crossing(h_fp32, eps)
        assert r_fp32 is not None, f"{name}: fp32 never reached eps={eps}"
        r_ef = _first_crossing(gap_history(wire, True), eps)
        assert r_ef is not None and r_ef <= 2 * r_fp32, \
            f"{name} {wire}+ef: crossed at {r_ef}, fp32 at {r_fp32}"
        r_no_ef = _first_crossing(gap_history(wire, False), eps)
        assert r_no_ef is None, \
            f"{name} {wire}-ef: quantization noise floor should hold the " \
            f"gap above eps={eps} forever, but it crossed at {r_no_ef}"


@pytest.mark.slow
def test_eps_certified_stop_fires_under_quantization():
    """eps= early stopping itself runs ON the quantized exchange: the int8+EF
    run stops, at the gap-recorder crossing, within 2x the fp32 stop."""
    from benchmarks.common import make_ridge

    prob, _ = make_ridge(lam=1e-5, seed=2)
    graph = topo.torus_2d(4, 4)
    eps, rounds = 8.0, 520
    stops = {}
    for wire in ("fp32", "int8"):
        cfg = ColaConfig(kappa=1.0, wire=wire)
        res = run_cola(prob, graph, cfg, rounds, record_every=2,
                       recorder="gap", eps=eps)
        stops[wire] = res.history["stop_round"]
        assert stops[wire] is not None, f"{wire} never certified eps={eps}"
        assert res.history["gap"][-1] <= eps
    assert stops["int8"] <= 2 * stops["fp32"]


# ---------------------------------------------------------------------------
# config corners the wire rejects
# ---------------------------------------------------------------------------

def test_wire_config_rejections(ridge):
    graph = topo.ring(K)
    with pytest.raises(ValueError, match="pipeline requires a quantized"):
        run_cola(ridge, graph, ColaConfig(kappa=1.0, pipeline=True), 4)
    byz = attack.Byzantine(nodes=(0,), mode="sign_flip", scale=10.0, start=1)
    # attacks=/robust= now compose with the wire on the SIMULATOR (the
    # attacked payload is re-encoded, the gate judges decoded rows) — the
    # remaining composed corners still fail loudly
    with pytest.raises(NotImplementedError, match="pipeline"):
        run_cola(ridge, graph,
                 ColaConfig(kappa=1.0, wire="int8", pipeline=True), 4,
                 attacks=[byz])
    with pytest.raises(NotImplementedError, match="gossip_steps"):
        run_cola(ridge, graph,
                 ColaConfig(kappa=1.0, wire="int8", robust="trim",
                            gossip_steps=2), 4)
    with pytest.raises(NotImplementedError, match="grad_mode"):
        run_cola(ridge, graph,
                 ColaConfig(kappa=1.0, wire="int8", grad_mode="mixed"), 4)
    with pytest.raises(NotImplementedError, match="reset"):
        run_cola(ridge, graph,
                 ColaConfig(kappa=1.0, wire="int8", pipeline=True), 4,
                 active_schedule=_drop, leave_mode="reset")
    with pytest.raises(ValueError, match="wire="):
        run_cola(ridge, graph, ColaConfig(kappa=1.0, wire="int4"), 4)


# ---------------------------------------------------------------------------
# gossip-SGD + DP wire (the stateless pytree codec)
# ---------------------------------------------------------------------------

def _param_stack(seed: int = 0, k: int = 6):
    rng = np.random.default_rng(seed)
    return {"w": jnp.asarray(rng.standard_normal((k, 4, 3)), jnp.float32),
            "b": jnp.asarray(rng.standard_normal((k, 3)), jnp.float32)}


def test_gossip_wire_dense_path_and_rejections():
    params = _param_stack()
    k = len(params["b"])
    w = jnp.asarray(topo.metropolis_weights(topo.ring(k)), jnp.float32)
    mix = _param_mixer(GossipConfig(num_nodes=k, wire="int8"),
                       None, None, None)
    got = mix(w, params)
    want = mix_pytree(w, params, 1)
    for leaf in ("w", "b"):
        # mixing the codec view: within one int8 grid step of the fp32 mix
        assert np.max(np.abs(np.asarray(got[leaf] - want[leaf]))) < 0.05
        assert not np.array_equal(np.asarray(got[leaf]),
                                  np.asarray(want[leaf]))
    with pytest.raises(ValueError, match="dense path"):
        _param_mixer(GossipConfig(num_nodes=k, wire="int8"),
                     jax.make_mesh((1,), ("data",)), "data", 1)
    with pytest.raises(ValueError, match="robust"):
        _param_mixer(GossipConfig(num_nodes=k, wire="int8", robust="trim"),
                     None, None, None)


def test_wire_view_pytree_stateless_keyed():
    params = _param_stack(3)
    assert quant.wire_view_pytree(params, "fp32") is params
    key = quant.wire_stream(jax.random.PRNGKey(9))
    a = quant.wire_view_pytree(params, "int8", key)
    b = quant.wire_view_pytree(params, "int8", key)
    for leaf in ("w", "b"):
        assert a[leaf].shape == params[leaf].shape
        np.testing.assert_array_equal(np.asarray(a[leaf]),
                                      np.asarray(b[leaf]))


def test_dp_wire_reclip_guard_restores_sensitivity():
    """Codec rounding can INFLATE a clipped emission's norm; the DP path's
    re-clip guard must restore ||p|| <= clip exactly, keeping the 2*clip
    replace-one sensitivity the accountant assumes."""
    clip = 1.0
    params = privacy.clip_params(_param_stack(11), clip)
    wv = quant.wire_view_pytree(params, "int8",
                                quant.wire_stream(jax.random.PRNGKey(0)))

    def norms(p):
        leaves = jax.tree_util.tree_leaves(p)
        sq = sum(np.sum(np.asarray(x, np.float64).reshape(x.shape[0], -1)
                        ** 2, axis=1) for x in leaves)
        return np.sqrt(sq)

    assert np.any(norms(wv) > clip), \
        "fixture should exercise the guard (codec inflated no norm)"
    guarded = privacy.clip_params(wv, clip)
    assert np.all(norms(guarded) <= clip * (1 + 1e-5))


def test_noisy_dense_mix_wire_codec_keyed_deterministic():
    params = _param_stack(4)
    k = len(params["b"])
    w = jnp.asarray(topo.metropolis_weights(topo.ring(k)), jnp.float32)
    dp = privacy.DPConfig(clip=1.0, sigma=0.5)
    key = jax.random.PRNGKey(12)
    a = privacy.noisy_dense_mix(w, params, dp, key, wire_codec="int8")
    b = privacy.noisy_dense_mix(w, params, dp, key, wire_codec="int8")
    plain = privacy.noisy_dense_mix(w, params, dp, key)
    for leaf in ("w", "b"):
        np.testing.assert_array_equal(np.asarray(a[leaf]),
                                      np.asarray(b[leaf]))
        assert not np.array_equal(np.asarray(a[leaf]),
                                  np.asarray(plain[leaf]))
