"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import problems
from repro.core.cola import build_env
from repro.core.partition import make_partition
from repro.core.subproblem import SubproblemSpec, cd_solve_all
from repro.data import synthetic
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ops import cd_solve_pallas
from repro.models.attention import chunked_attention, reference_attention

# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

ATTN_CASES = [
    # (b, sq, skv, h, kvh, hd, mode, window)
    (2, 32, 32, 4, 2, 16, "causal", 0),
    (2, 32, 32, 4, 2, 16, "sliding", 8),
    (2, 32, 32, 4, 4, 16, "chunked_local", 8),
    (2, 8, 24, 4, 2, 16, "cross", 0),
    (1, 1, 40, 8, 2, 32, "causal", 0),      # decode shape
    (2, 17, 23, 8, 2, 32, "causal", 0),     # non-multiples of block
    (1, 64, 64, 2, 1, 64, "sliding", 16),
    (3, 5, 37, 6, 3, 8, "chunked_local", 4),
]


@pytest.mark.parametrize("case", ATTN_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_reference(case, dtype):
    b, sq, skv, h, kvh, hd, mode, window = case
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, sq, h, hd), dtype)
    k = jax.random.normal(ks[1], (b, skv, kvh, hd), dtype)
    v = jax.random.normal(ks[2], (b, skv, kvh, hd), dtype)
    q_pos = jnp.tile(jnp.arange(skv - sq, skv), (b, 1)).astype(jnp.int32)
    kv_pos = jnp.tile(jnp.arange(skv), (b, 1)).astype(jnp.int32)
    out = flash_attention(q, k, v, q_pos, kv_pos, mode=mode, window=window,
                          block_q=16, block_kv=16)
    ref = reference_attention(q, k, v, q_pos, kv_pos, mode=mode,
                              window=window)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol,
                               rtol=tol)


def test_flash_attention_ring_buffer_positions():
    """Rotated (ring-buffer) kv_pos with empty (-1) slots."""
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    b, skv, kvh, hd = 2, 24, 2, 16
    q = jax.random.normal(ks[0], (b, 1, 4, hd))
    k = jax.random.normal(ks[1], (b, skv, kvh, hd))
    v = jax.random.normal(ks[2], (b, skv, kvh, hd))
    kv_pos = jnp.tile((jnp.arange(skv) + 7) % skv, (b, 1)).astype(jnp.int32)
    kv_pos = kv_pos.at[:, -4:].set(-1)  # empty slots
    q_pos = jnp.full((b, 1), skv + 2, jnp.int32)
    out = flash_attention(q, k, v, q_pos, kv_pos, mode="sliding", window=10,
                          block_q=8, block_kv=8)
    ref = reference_attention(q, k, v, q_pos, kv_pos, mode="sliding",
                              window=10)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@settings(max_examples=15, deadline=None)
@given(skv=st.integers(8, 48), sq_frac=st.floats(0.05, 1.0),
       g=st.sampled_from([1, 2, 4]), seed=st.integers(0, 100))
def test_flash_attention_property_shapes(skv, sq_frac, g, seed):
    sq = max(1, int(sq_frac * skv))  # queries are the suffix of the kv span
    kvh, hd = 2, 8
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (1, sq, kvh * g, hd))
    k = jax.random.normal(ks[1], (1, skv, kvh, hd))
    v = jax.random.normal(ks[2], (1, skv, kvh, hd))
    q_pos = jnp.arange(skv - sq, skv).reshape(1, -1).astype(jnp.int32)
    kv_pos = jnp.arange(skv).reshape(1, -1).astype(jnp.int32)
    out = flash_attention(q, k, v, q_pos, kv_pos, mode="causal",
                          block_q=16, block_kv=16)
    ref = reference_attention(q, k, v, q_pos, kv_pos, mode="causal")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)


def test_chunked_attention_matches_reference_all_modes():
    """The scan-based oracle itself vs the naive reference."""
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    b, s, h, kvh, hd = 2, 40, 4, 2, 16
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, kvh, hd))
    v = jax.random.normal(ks[2], (b, s, kvh, hd))
    pos = jnp.tile(jnp.arange(s), (b, 1)).astype(jnp.int32)
    for mode, window in [("causal", 0), ("sliding", 8),
                         ("chunked_local", 8), ("cross", 0)]:
        out = chunked_attention(q, k, v, pos, pos, mode=mode, window=window,
                                kv_chunk=16)
        ref = reference_attention(q, k, v, pos, pos, mode=mode, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-5, err_msg=mode)


# ---------------------------------------------------------------------------
# CD GLM kernel
# ---------------------------------------------------------------------------

def _problem(name, seed=0):
    x, y, _ = synthetic.regression(64, 36, seed=seed)
    xj, yj = jnp.asarray(x), jnp.asarray(y)
    if name.startswith("logistic"):
        yj = jnp.sign(yj) + (jnp.sign(yj) == 0)
    return problems.PROBLEMS[name](xj, yj, 1e-2)


@pytest.mark.parametrize("name", sorted(problems.PROBLEMS))
@pytest.mark.parametrize("k,steps_mult", [(2, 1), (4, 2), (6, 3)])
def test_cd_kernel_matches_oracle(name, k, steps_mult):
    prob = _problem(name)
    part = make_partition(prob.n, k)
    env = build_env(prob, part)
    key = jax.random.PRNGKey(k)
    x_parts = 0.1 * jax.random.normal(key, (k, part.block))
    vs = 0.3 * jax.random.normal(key, (k, prob.d))
    grads = jax.vmap(prob.grad_f)(vs)
    spec = SubproblemSpec(sigma_over_tau=k / prob.tau, inv_k=1.0 / k)
    steps = steps_mult * part.block
    ref = cd_solve_all(prob, spec, env.a_parts, x_parts, grads,
                       env.gp_parts, env.masks, steps)
    out = cd_solve_pallas(prob, spec, env.a_parts, x_parts, grads,
                          env.gp_parts, env.masks, steps)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 50), frac=st.floats(0.2, 1.0))
def test_cd_kernel_partial_pass_property(seed, frac):
    """Fractional kappa (< one pass) still matches the oracle exactly."""
    prob = _problem("lasso", seed=seed)
    k = 4
    part = make_partition(prob.n, k)
    env = build_env(prob, part)
    grads = jax.vmap(prob.grad_f)(
        0.2 * jax.random.normal(jax.random.PRNGKey(seed), (k, prob.d)))
    x_parts = jnp.zeros((k, part.block))
    spec = SubproblemSpec(sigma_over_tau=k / prob.tau, inv_k=1.0 / k)
    steps = max(1, int(frac * part.block))
    ref = cd_solve_all(prob, spec, env.a_parts, x_parts, grads,
                       env.gp_parts, env.masks, steps)
    out = cd_solve_pallas(prob, spec, env.a_parts, x_parts, grads,
                          env.gp_parts, env.masks, steps)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_cd_kernel_decreases_subproblem_objective():
    """The kernel's dx must decrease G_k (Assumption 1 with Theta < 1)."""
    from repro.core.subproblem import eval_subproblem
    prob = _problem("ridge_primal")
    k = 4
    part = make_partition(prob.n, k)
    env = build_env(prob, part)
    vs = 0.3 * jax.random.normal(jax.random.PRNGKey(9), (k, prob.d))
    grads = jax.vmap(prob.grad_f)(vs)
    x_parts = jnp.zeros((k, part.block))
    spec = SubproblemSpec(sigma_over_tau=k / prob.tau, inv_k=1.0 / k)
    dx = cd_solve_pallas(prob, spec, env.a_parts, x_parts, grads,
                         env.gp_parts, env.masks, part.block)
    for i in range(k):
        g0 = eval_subproblem(prob, spec, env.a_parts[i], x_parts[i],
                             jnp.zeros_like(dx[i]), vs[i], grads[i],
                             env.gp_parts[i], env.masks[i])
        g1 = eval_subproblem(prob, spec, env.a_parts[i], x_parts[i], dx[i],
                             vs[i], grads[i], env.gp_parts[i], env.masks[i])
        assert float(g1) <= float(g0) + 1e-6
