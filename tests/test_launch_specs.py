"""Launch-layer unit tests that don't need the 512-device mesh: input specs,
collective-byte parsing, replica-group materialization, Opts tagging."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# Initialize the backend on the single real device BEFORE anything imports
# repro.launch.dryrun (which prepends the 512-device XLA flag for new
# processes; jax locks the device count on first init, so this pin wins).
jax.devices()

from repro.configs.base import ARCHS, SHAPES, get_config
from repro.launch.hlo_analysis import _replica_groups, _spans_pods
from repro.launch.specs import cache_specs, input_specs, params_specs


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("shape_name", list(SHAPES))
def test_input_specs_shapes(arch, shape_name):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        pytest.skip("documented long-context skip")
    batch = input_specs(cfg, shape)
    b = shape.global_batch
    assert batch["tokens"].dtype == jnp.int32
    assert batch["tokens"].shape[0] == b
    if shape.kind == "decode":
        assert batch["tokens"].shape == (b, 1)
    if shape.kind == "train":
        assert batch["labels"].shape == batch["tokens"].shape
    if cfg.family == "vlm" and shape.kind != "decode":
        assert batch["patches"].shape[1] == cfg.num_prefix_tokens


@pytest.mark.parametrize("arch", ["h2o_danube3_4b", "llama4_maverick_400b",
                                  "zamba2_7b", "xlstm_125m"])
def test_decode_cache_is_bounded_for_subquadratic(arch):
    """long_500k decode state must NOT scale with the 524288-token context."""
    cfg = get_config(arch)
    cache = cache_specs(cfg, 1, SHAPES["long_500k"].seq_len)
    import jax
    total = sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(cache))
    assert total < 3e9, f"{arch}: decode state {total/1e9:.1f} GB"


def test_replica_groups_iota_format():
    g = _replica_groups("replica_groups=[2,4]<=[8]")
    np.testing.assert_array_equal(np.asarray(g),
                                  [[0, 1, 2, 3], [4, 5, 6, 7]])
    # transposed iota: [4,2]<=[2,4]T(1,0) -> groups of stride-4 pairs
    g = _replica_groups("replica_groups=[4,2]<=[2,4]T(1,0)")
    np.testing.assert_array_equal(np.asarray(g),
                                  [[0, 4], [1, 5], [2, 6], [3, 7]])


def test_replica_groups_explicit_format():
    g = _replica_groups("replica_groups={{0,1},{2,3}}")
    assert g == [[0, 1], [2, 3]]


def test_spans_pods():
    # pod size 4: group [0..3] stays, [2,6] crosses
    assert not _spans_pods("replica_groups=[2,4]<=[8]", 4)
    assert _spans_pods("replica_groups={{2,6},{3,7}}", 4)
    assert _spans_pods("no groups here", 4)  # conservative default


def test_opts_tag():
    from repro.launch import dryrun  # noqa: deferred heavy import
    # NOTE: importing dryrun sets XLA_FLAGS for NEW processes only; this
    # process already initialized jax with 1 device.
    assert dryrun.Opts().tag() == "baseline"
    t = dryrun.Opts(attn_bf16=True, microbatches=4, moe_grouped=True).tag()
    assert "attnbf16" in t and "mb4" in t and "moegrp" in t
