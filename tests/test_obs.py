"""repro.obs: counter totals vs the plan-contract ground truth, the
bitwise telemetry-off pin, the run registry + diff classifier, trace/cache
listeners, and the telemetry-carry lint.

The acceptance scenario from the issue rides `test_counters_match_contract`
and `test_gate_rejections_only_dishonest`: on the fig3 torus with an int8
wire, trim mixing and a seeded 2-node sign-flip attack, the wire-byte
counter equals the contract budget exactly and gate rejections land only on
`atk_dishonest` sender columns — while `test_telemetry_off_bitwise_sim`
pins the off-twin to today's histories.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax.numpy as jnp

from repro import attack, topo as topo_programs
from repro.core import executor as exec_engine, problems
from repro.core.cola import ColaConfig, run_cola
from repro.data import synthetic
from repro.obs import report as obs_report
from repro.obs.cli import sparkline

ROUNDS = 10


@pytest.fixture(autouse=True)
def _registry_off(monkeypatch):
    # keep CI checkouts clean: no test run appends to .repro_runs unless it
    # points REPRO_RUNS_DIR at its own tmpdir
    monkeypatch.setenv(obs_report.ENV_DIR, "off")


@pytest.fixture(scope="module")
def prob():
    x, y, _ = synthetic.regression(120, 48, seed=1, sparsity_solution=0.2)
    return problems.lasso(jnp.asarray(x), jnp.asarray(y), 1e-3)


@pytest.fixture(scope="module")
def graph():
    return topo_programs.build("torus2d", 16)


def _byz():
    return [attack.Byzantine(nodes=(1, 6), mode="sign_flip", scale=10.0,
                             start=4)]


def _assert_history_equal(h_off, h_on):
    assert set(h_off) == set(h_on) - {"telemetry"}
    for key, val in h_off.items():
        got = h_on[key]
        if isinstance(val, (list, np.ndarray)):
            assert np.array_equal(np.asarray(val), np.asarray(got)), key
        else:
            assert val == got, key


@pytest.mark.parametrize("kw", [
    dict(),
    dict(wire="int8"),
    dict(wire="int8", robust="trim"),
])
def test_telemetry_off_bitwise_sim(prob, graph, kw):
    """Turning counters on must not change one bit of the computation."""
    attacks = _byz() if "robust" in kw else None
    runs = {}
    for tel in (False, True):
        cfg = ColaConfig(kappa=1.0, telemetry=tel, **kw)
        runs[tel] = run_cola(prob, graph, cfg, ROUNDS, attacks=attacks)
    assert np.array_equal(np.asarray(runs[False].state.x_parts),
                          np.asarray(runs[True].state.x_parts))
    _assert_history_equal(runs[False].history, runs[True].history)


@pytest.mark.parametrize("wire", ["fp32", "int8"])
def test_counters_match_contract(prob, graph, wire):
    """The byte/permute counters equal rounds x the SAME budget the static
    contract verifier holds the compiled HLO to — no independent model."""
    w = None if wire == "fp32" else wire
    contract = topo_programs.compile_plan(graph).contract(prob.d, wire=w)
    cfg = ColaConfig(kappa=1.0, wire=wire, telemetry=True)
    tel = run_cola(prob, graph, cfg, ROUNDS).history["telemetry"]
    assert tel["rounds"] == ROUNDS
    assert tel["wire_bytes"] == ROUNDS * contract.max_collective_permute_bytes
    assert tel["permutes"] == ROUNDS * contract.max_collective_permute_count
    assert tel["contract"] == contract.describe()
    if wire == "int8":
        assert 0.0 <= tel["saturation_mean"] < 1.0
        assert tel["ef_norm"] > 0.0


def test_gate_rejections_only_dishonest(prob, graph):
    cfg = ColaConfig(kappa=1.0, wire="int8", robust="trim", telemetry=True)
    tel = run_cola(prob, graph, cfg, ROUNDS,
                   attacks=_byz()).history["telemetry"]
    assert tel["dishonest_nodes"] == [1, 6]
    assert tel["gate_dishonest"] >= 1
    assert tel["gate_honest"] == 0
    gate = np.asarray(tel["gate_rejections"])
    assert gate.sum() == tel["gate_total"] == tel["gate_dishonest"]
    assert set(np.nonzero(gate)[0]) == {1, 6}
    # a clean run under the same defense rejects nobody
    clean = run_cola(prob, graph, cfg, ROUNDS).history["telemetry"]
    assert clean["gate_total"] == 0


def test_report_roundtrip_and_find(prob, graph, tmp_path, monkeypatch):
    monkeypatch.setenv(obs_report.ENV_DIR, str(tmp_path))
    cfg = ColaConfig(kappa=1.0, telemetry=True)
    run_cola(prob, graph, cfg, ROUNDS)
    run_cola(prob, graph, cfg, ROUNDS)
    reports = obs_report.load_reports()
    assert len(reports) == 2
    rep = obs_report.RunReport.from_dict(reports[-1])
    assert rep.driver == "run_cola"
    assert rep.rounds == ROUNDS
    assert rep.counters["wire_bytes"] > 0
    assert rep.series["round"] == list(range(ROUNDS))
    assert "block-first-dispatch" in rep.spans["spans"]
    # ref resolution: negative index and run_id prefix hit the same record
    assert obs_report.find_report("-1", reports) == reports[-1]
    assert obs_report.find_report(rep.run_id[:6], reports) == reports[-1]
    with pytest.raises(KeyError):
        obs_report.find_report("nope", reports)


def test_diff_only_telemetry(prob, graph, tmp_path, monkeypatch):
    """Two runs that computed the same thing diff to telemetry-only; a
    different wire does not."""
    monkeypatch.setenv(obs_report.ENV_DIR, str(tmp_path))
    run_cola(prob, graph, ColaConfig(kappa=1.0, telemetry=True), ROUNDS)
    run_cola(prob, graph, ColaConfig(kappa=1.0, telemetry=True), ROUNDS)
    run_cola(prob, graph,
             ColaConfig(kappa=1.0, wire="int8", telemetry=True), ROUNDS)
    reports = obs_report.load_reports()
    twin = obs_report.diff_reports(reports[0], reports[1])
    assert twin["only_telemetry"]
    assert twin["history"] == {}
    wired = obs_report.diff_reports(reports[0], reports[2])
    assert not wired["only_telemetry"]
    assert "wire" in wired["config"]
    # diffing is stable: same inputs, same structured delta
    assert obs_report.diff_reports(reports[0], reports[1]) == twin


def test_registry_retention_prunes_oldest_first(tmp_path, monkeypatch):
    """The JSONL registry is capped (REPRO_RUNS_KEEP, default 200): the
    append path prunes oldest-first, keeps order, and accounts the total
    pruned in the sidecar `obs list` reports."""
    monkeypatch.setenv(obs_report.ENV_DIR, str(tmp_path))
    monkeypatch.setenv(obs_report.ENV_KEEP, "5")
    for i in range(9):
        obs_report.append_report({"run_id": f"run{i:02d}", "rounds": i})
    reports = obs_report.load_reports()
    assert [r["run_id"] for r in reports] == \
        [f"run{i:02d}" for i in range(4, 9)]
    assert obs_report.pruned_total() == 4
    # the cap is re-enforced on every append, not only at the threshold
    obs_report.append_report({"run_id": "run09", "rounds": 9})
    assert len(obs_report.load_reports()) == 5
    assert obs_report.pruned_total() == 5


def test_registry_retention_env_and_overrides(tmp_path, monkeypatch):
    monkeypatch.setenv(obs_report.ENV_DIR, str(tmp_path))
    monkeypatch.delenv(obs_report.ENV_KEEP, raising=False)
    assert obs_report.retention_limit() == obs_report.DEFAULT_KEEP
    assert obs_report.retention_limit(keep=7) == 7
    monkeypatch.setenv(obs_report.ENV_KEEP, "3")
    assert obs_report.retention_limit() == 3
    # keep= beats the env; <= 0 disables pruning entirely
    for i in range(6):
        obs_report.append_report({"run_id": f"r{i}"}, keep=0)
    assert len(obs_report.load_reports()) == 6
    assert obs_report.pruned_total() == 0
    obs_report.append_report({"run_id": "r6"})  # env cap=3 kicks in
    assert len(obs_report.load_reports()) == 3
    assert obs_report.pruned_total() == 4
    monkeypatch.setenv(obs_report.ENV_KEEP, "many")
    with pytest.raises(ValueError, match="REPRO_RUNS_KEEP"):
        obs_report.retention_limit()


def test_obs_list_reports_pruned_count(tmp_path, monkeypatch, capsys):
    from repro.obs import cli as obs_cli
    monkeypatch.setenv(obs_report.ENV_DIR, str(tmp_path))
    monkeypatch.setenv(obs_report.ENV_KEEP, "2")
    for i in range(4):
        obs_report.append_report({"run_id": f"run{i}", "rounds": i})
    assert obs_cli.main(["--dir", str(tmp_path), "list"]) == 0
    out = capsys.readouterr().out
    assert "2 older run(s) pruned by retention" in out
    assert obs_report.ENV_KEEP in out


def test_cache_listener_nesting():
    outer, inner = [], []
    exec_engine.cached_driver(("obs-test", 0), lambda: (lambda: None))
    with exec_engine.cache_listener(lambda k, kind: outer.append(kind)):
        with exec_engine.cache_listener(lambda k, kind: inner.append(kind)):
            exec_engine.cached_driver(("obs-test", 0), lambda: (lambda: None))
        assert inner == ["hits"] and outer == ["hits"]
        exec_engine.cached_driver(("obs-test", 1), lambda: (lambda: None))
    assert inner == ["hits"]          # removed with its scope
    assert outer == ["hits", "misses"]
    exec_engine.cached_driver(("obs-test", 1), lambda: (lambda: None))
    assert outer == ["hits", "misses"]  # both scopes closed: no leak


def test_telemetry_carry_pass():
    """The lint fires on counters captured as constants (seeded in
    analysis.selftest) and stays quiet when the counter genuinely extends
    the scan carry."""
    import jax
    from jax import lax
    from repro.analysis import passes
    from repro.analysis.selftest import seeded_telemetry_constant

    assert seeded_telemetry_constant(), \
        "telemetry-carry pass missed its seeded constant-counter violation"

    def run_off(x):
        return lax.scan(lambda c, _: (c + 1.0, None), x, None, length=4)[0]

    def run_on(x):
        def step(carry, _):
            c, wire_bytes = carry
            return (c + 1.0, wire_bytes + 64.0), None
        return lax.scan(step, (x, jnp.zeros(())), None, length=4)[0][0]

    off = jax.make_jaxpr(run_off)(jnp.float32(0.0))
    on = jax.make_jaxpr(run_on)(jnp.float32(0.0))
    assert passes.telemetry_carry(off, on, where="test:carried") == []


def test_sparkline():
    rising = sparkline([float(i) for i in range(32)], width=16)
    assert len(rising) == 16
    assert rising[-1] == "█"
    assert sparkline([1.0, 1.0, 1.0], width=8)  # constant series: no crash
    # short series are not padded: one cell per point
    assert len(sparkline([2.0, 4.0], width=8, log=True)) == 2


def test_telemetry_requires_block_executor(prob, graph):
    with pytest.raises(ValueError, match="telemetry"):
        run_cola(prob, graph, ColaConfig(kappa=1.0, telemetry=True),
                 ROUNDS, executor="loop")


# --- the shard_map runtime's counters on 1- and 4-device meshes, in a
# subprocess so the suite keeps the single real CPU device (dry-run rule)

DIST_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    os.environ["REPRO_RUNS_DIR"] = "off"
    import jax, jax.numpy as jnp, numpy as np
    from repro import topo as topo_programs
    from repro.core import problems
    from repro.data import synthetic
    from repro.core.cola import ColaConfig
    from repro.dist.runtime import run_dist_cola

    x, y, _ = synthetic.regression(120, 48, seed=1, sparsity_solution=0.2)
    prob = problems.lasso(jnp.asarray(x), jnp.asarray(y), 1e-3)
    graph = topo_programs.build("torus2d", 16)
    rounds = 10
    for nd in (1, 4):
        mesh = jax.make_mesh((nd,), ("data",))
        for wire in ("fp32", "int8"):
            runs = {}
            for tel in (False, True):
                cfg = ColaConfig(kappa=1.0, wire=wire, telemetry=tel)
                runs[tel] = run_dist_cola(prob, graph, cfg, mesh, rounds,
                                          comm="plan")
            assert np.array_equal(
                np.asarray(runs[False].state.x_parts),
                np.asarray(runs[True].state.x_parts)), (nd, wire)
            tel = runs[True].history["telemetry"]
            w = None if wire == "fp32" else wire
            if nd == 1:
                # K=16 on one device: every edge is intra-block, no wire
                assert tel["wire_bytes"] == 0, (nd, wire, tel)
            else:
                bplan = topo_programs.compile_block_plan(graph, nd)
                c = bplan.contract(prob.d, wire=w)
                assert tel["wire_bytes"] == \\
                    rounds * c.max_collective_permute_bytes, (nd, wire, tel)
                assert tel["permutes"] == \\
                    rounds * c.max_collective_permute_count, (nd, wire, tel)
    print("OBS_DIST_OK")
""")


@pytest.mark.slow
def test_dist_counters_and_bitwise_off_twin():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", DIST_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert "OBS_DIST_OK" in out.stdout, out.stdout + "\n" + out.stderr
