"""Trip-count-aware HLO analyzer vs hand-computed costs."""
import jax
import jax.numpy as jnp
import pytest
from jax import lax

from repro.launch.hlo_analysis import analyze


def _compile_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


@pytest.mark.parametrize("length", [2, 8, 32])
def test_scan_matmul_flops_scale_with_trip_count(length):
    def f(x, w):
        def body(c, wl):
            return jnp.tanh(c @ wl), None
        y, _ = lax.scan(body, x, w)
        return y.sum()

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((length, 128, 128), jnp.float32)
    r = analyze(_compile_text(f, x, w))
    expect = length * 2 * 128 ** 3
    assert 0.95 * expect <= r["flops"] <= 1.1 * expect


def test_nested_scan_multiplicity():
    def g(x, w):
        def outer(c, wl):
            def inner(c2, _):
                return jnp.tanh(c2 @ wl), None
            c2, _ = lax.scan(inner, c, None, length=4)
            return c2, None
        y, _ = lax.scan(outer, x, w)
        return y.sum()

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)
    r = analyze(_compile_text(g, x, w))
    expect = 8 * 4 * 2 * 128 ** 3
    assert 0.95 * expect <= r["flops"] <= 1.1 * expect


def test_fori_loop_trip_count():
    def f(x):
        return lax.fori_loop(0, 11, lambda i, c: jnp.tanh(c @ c), x)

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    r = analyze(_compile_text(f, x))
    expect = 11 * 2 * 64 ** 3
    assert 0.9 * expect <= r["flops"] <= 1.2 * expect


def test_dot_general_contraction_dims():
    def f(a, b):
        return jnp.einsum("bij,bjk->bik", a, b)

    a = jax.ShapeDtypeStruct((4, 32, 48), jnp.float32)
    b = jax.ShapeDtypeStruct((4, 48, 16), jnp.float32)
    r = analyze(_compile_text(f, a, b))
    expect = 2 * 4 * 32 * 16 * 48
    assert 0.95 * expect <= r["flops"] <= 1.3 * expect


def test_traffic_counts_dot_operands_not_sliced_stacks():
    """The scan weight fetch reads one layer per trip, not the whole stack."""
    L, D = 16, 256

    def f(x, w):
        def body(c, wl):
            return c @ wl, None
        y, _ = lax.scan(body, x, w)
        return y.sum()

    x = jax.ShapeDtypeStruct((D, D), jnp.float32)
    w = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
    r = analyze(_compile_text(f, x, w))
    # per trip: read w_l + read c + write out  (+ slice traffic) ~ 4 * D*D*4B
    per_trip = 4 * D * D * 4
    stack_bytes = L * D * D * 4
    # stack-read-per-trip would be >= L * stack_bytes (67 MB here); the
    # aliasing-aware model stays well under that while seeing real traffic
    assert r["bytes"] < L * stack_bytes * 0.7
    assert r["bytes"] >= L * per_trip * 0.5
