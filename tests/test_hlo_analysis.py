"""Trip-count-aware HLO analyzer vs hand-computed costs."""
import textwrap

import jax
import jax.numpy as jnp
import pytest
from jax import lax

from repro.launch import hlo_analysis
from repro.launch.hlo_analysis import analyze


def _compile_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


@pytest.mark.parametrize("length", [2, 8, 32])
def test_scan_matmul_flops_scale_with_trip_count(length):
    def f(x, w):
        def body(c, wl):
            return jnp.tanh(c @ wl), None
        y, _ = lax.scan(body, x, w)
        return y.sum()

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((length, 128, 128), jnp.float32)
    r = analyze(_compile_text(f, x, w))
    expect = length * 2 * 128 ** 3
    assert 0.95 * expect <= r["flops"] <= 1.1 * expect


def test_nested_scan_multiplicity():
    def g(x, w):
        def outer(c, wl):
            def inner(c2, _):
                return jnp.tanh(c2 @ wl), None
            c2, _ = lax.scan(inner, c, None, length=4)
            return c2, None
        y, _ = lax.scan(outer, x, w)
        return y.sum()

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)
    r = analyze(_compile_text(g, x, w))
    expect = 8 * 4 * 2 * 128 ** 3
    assert 0.95 * expect <= r["flops"] <= 1.1 * expect


def test_fori_loop_trip_count():
    def f(x):
        return lax.fori_loop(0, 11, lambda i, c: jnp.tanh(c @ c), x)

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    r = analyze(_compile_text(f, x))
    expect = 11 * 2 * 64 ** 3
    assert 0.9 * expect <= r["flops"] <= 1.2 * expect


def test_dot_general_contraction_dims():
    def f(a, b):
        return jnp.einsum("bij,bjk->bik", a, b)

    a = jax.ShapeDtypeStruct((4, 32, 48), jnp.float32)
    b = jax.ShapeDtypeStruct((4, 48, 16), jnp.float32)
    r = analyze(_compile_text(f, a, b))
    expect = 2 * 4 * 32 * 16 * 48
    assert 0.95 * expect <= r["flops"] <= 1.3 * expect


def test_traffic_counts_dot_operands_not_sliced_stacks():
    """The scan weight fetch reads one layer per trip, not the whole stack."""
    L, D = 16, 256

    def f(x, w):
        def body(c, wl):
            return c @ wl, None
        y, _ = lax.scan(body, x, w)
        return y.sum()

    x = jax.ShapeDtypeStruct((D, D), jnp.float32)
    w = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
    r = analyze(_compile_text(f, x, w))
    # per trip: read w_l + read c + write out  (+ slice traffic) ~ 4 * D*D*4B
    per_trip = 4 * D * D * 4
    stack_bytes = L * D * D * 4
    # stack-read-per-trip would be >= L * stack_bytes (67 MB here); the
    # aliasing-aware model stays well under that while seeing real traffic
    assert r["bytes"] < L * stack_bytes * 0.7
    assert r["bytes"] >= L * per_trip * 0.5


# --- shape-parser coverage: f8 dtypes and zero-payload types ---------------

def test_parse_shapes_counts_f8_dtypes():
    shapes = hlo_analysis._parse_shapes("(f8e4m3fn[8,16], f8e5m2[4], f32[4])")
    assert ("f8e4m3fn", (8, 16)) in shapes
    assert ("f8e5m2", (4,)) in shapes
    assert hlo_analysis._bytes_of("f8e4m3fn[8,16]") == 8 * 16
    assert hlo_analysis._bytes_of("f8e5m2fnuz[10]") == 10


def test_parse_shapes_keeps_tokens_as_zero_bytes():
    """token[]/opaque[] parse as zero-element entries instead of being
    silently dropped — a tuple mixing them with arrays keeps array bytes."""
    mixed = "(f32[8], token[], opaque[])"
    shapes = hlo_analysis._parse_shapes(mixed)
    assert ("token", (0,)) in shapes
    assert ("opaque", (0,)) in shapes
    assert hlo_analysis._bytes_of(mixed) == 8 * 4
    assert hlo_analysis._bytes_of("token[]") == 0
    assert hlo_analysis._elems_of("token[]") == 0


def test_analyze_counts_f8_collective_permute():
    """An f8 ppermute used to contribute ZERO bytes (dtype missing from the
    table) — a quantized-payload gossip step would have passed any byte
    budget vacuously."""
    hlo = textwrap.dedent("""\
        HloModule m

        ENTRY %main (p0: f8e5m2[64]) -> f8e5m2[64] {
          %p0 = f8e5m2[64] parameter(0)
          ROOT %cp = f8e5m2[64] collective-permute(%p0), source_target_pairs={{0,1},{1,0}}
        }
    """)
    r = analyze(hlo)
    assert r["collectives"]["collective-permute"] == 64  # 1 byte/elem
    assert r["collective_counts"]["collective-permute"] == 1


def test_analyze_token_tuple_collective():
    """A collective whose result tuple carries a token still counts its
    array payload (and the token adds nothing)."""
    hlo = textwrap.dedent("""\
        HloModule m

        ENTRY %main (p0: f32[16]) -> (f32[16], token[]) {
          %p0 = f32[16] parameter(0)
          ROOT %ar = (f32[16], token[]) all-reduce(%p0), replica_groups={{0,1}}
        }
    """)
    r = analyze(hlo)
    # all-reduce bytes count x2 (reduce + broadcast)
    assert r["collectives"]["all-reduce"] == 2 * 16 * 4
