"""Shared fixtures. NOTE: no XLA_FLAGS here on purpose — tests must see the
single real CPU device; only the dry-run forces 512 placeholder devices
(and the shard_map equivalence tests spawn their own subprocess)."""
import jax
import numpy as np
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(autouse=True)
def _no_nan_debug():
    yield
