"""Elastic decentralized LASSO: nodes drop out and re-join mid-training,
and the run stops itself by Prop.-1 certification.

Reproduces the Fig.-4 fault-tolerance setting in miniature: every round each
node stays in the network with probability p; leavers freeze their block
(Theta_k = 1) and the surviving nodes re-normalize the Metropolis weights.
CoLA keeps converging monotonically — no tuning, no restart — and instead
of a fixed round count, ``eps=`` arms the local certificates: the run
terminates at the first record round where every node certifies the global
duality gap from its own neighborhood, churn and all.

The gossip graph is any name from the ``repro.topo`` registry (default: a
2-D torus — non-circulant, so on a device mesh this exact schedule executes
through the compiled topology program at neighbor-only cost; the compiled
plan is printed). Recording runs on the adaptive cadence: geometric
back-off while far from eps, tightening to every round near certification.

With ``--byzantine`` some nodes LIE instead of leaving: they emit
sign-flipped x10 payloads from round 5 on (the ``repro.attack`` harness,
composed on top of the same churn schedule). Undefended, the honest-cohort
certificate detects the tampering (``certificate_violated``); with
``--robust trim`` the mixing layer drops flagged payloads and the run
converges and certifies as if clean. Composing the attack with heavy churn
(low ``--p-stay``) thins neighborhoods until a liar can dominate one and
slip the outlier gate — in that regime the certificate fires instead of
the defense holding: either way a lying participant is never silent. Use
``--p-stay 1.0`` to see the defense hold cleanly.

With ``--wire fp8`` (or ``int8``) the gossip payloads cross the wire as
1-byte codewords plus a per-node fp32 absmax scale (``repro.core.quant``)
— the printed plan shows the byte budget shrinking to ~0.25x — while
error feedback carries the rounding residual across rounds, so the run
still certifies the SAME eps; ``--no-error-feedback`` shows the contrast
(the quantization noise floor can hold the gap above a tight eps
forever). The codec composes with churn AND with ``--byzantine`` /
``--robust``: attacked payloads are re-encoded onto the same wire, the
outlier gate judges the decoded rows, and error feedback rides the honest
stream only.

With ``--telemetry`` the run carries the ``repro.obs`` counters through the
round scan — wire bytes vs the printed plan's contract, robust-gate
rejections per sender, quantizer saturation, EF residual norm — and prints
the totals; ``--report`` additionally appends a ``RunReport`` to the run
registry (``.repro_runs/`` or ``$REPRO_RUNS_DIR``) for
``python -m repro.obs show/diff/timeline``.

  PYTHONPATH=src python examples/elastic_lasso.py [--topo torus2d]
      [--p-stay 0.8] [--eps 3.0] [--byzantine 0,10] [--robust trim]
      [--wire fp8] [--no-error-feedback] [--telemetry] [--report]
"""
import argparse
import os

import jax.numpy as jnp
import numpy as np

from repro import attack, topo as topo_programs
from repro.core import metrics as metrics_lib, problems
from repro.core.cola import ColaConfig, run_cola, solve_reference
from repro.data import synthetic


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--p-stay", type=float, default=0.8)
    ap.add_argument("--eps", type=float, default=3.0,
                    help="certified duality-gap target (stops the run)")
    ap.add_argument("--rounds", type=int, default=1500,
                    help="round budget: max rounds if certification "
                         "never fires")
    ap.add_argument("--topo", default="torus2d",
                    help="gossip graph (repro.topo.GRAPHS name)")
    ap.add_argument("--byzantine", default=None, metavar="NODES",
                    help="comma-separated node ids that emit sign-flipped "
                         "x10 payloads from round 5 on (e.g. '0,10')")
    ap.add_argument("--robust", default=None,
                    choices=["trim", "median", "clip"],
                    help="robust mixing defense (default: trust everyone)")
    ap.add_argument("--wire", default="fp32",
                    choices=["fp32", "fp8", "fp8_e5m2", "int8"],
                    help="gossip wire codec: quantize payloads to 1-byte "
                         "codewords + fp32 absmax scales (default fp32)")
    ap.add_argument("--no-error-feedback", action="store_true",
                    help="disable the EF residual carry — shows the raw "
                         "quantization noise floor")
    ap.add_argument("--telemetry", action="store_true",
                    help="carry the repro.obs counters through the round "
                         "scan and print the totals")
    ap.add_argument("--report", action="store_true",
                    help="also append a RunReport to the run registry "
                         "(implies --telemetry)")
    args = ap.parse_args()
    quantized = args.wire != "fp32"
    telemetry = args.telemetry or args.report
    if telemetry and not args.report:
        # counters only: keep the registry untouched unless the user
        # already pointed REPRO_RUNS_DIR somewhere
        os.environ.setdefault("REPRO_RUNS_DIR", "off")

    x, y, _ = synthetic.regression(1500, 300, seed=1, sparsity_solution=0.1)
    prob = problems.lasso(jnp.asarray(x), jnp.asarray(y), lam=1e-3)
    opt = solve_reference(prob, rounds=500, kappa=8)
    k = 16
    graph = topo_programs.build(args.topo, k)

    # the comm program a device mesh would execute for this graph — churn
    # reweighting rides the same compiled permutations with zeroed weights
    plan = topo_programs.compile_plan(graph)
    print(plan.render(d=prob.d, wire=args.wire if quantized else None))
    if quantized:
        ef = not args.no_error_feedback
        print(f"wire={args.wire} error_feedback={'on' if ef else 'OFF'}: "
              "payloads quantized per (round, node) with stochastic "
              "rounding; the certificate stop below runs on the quantized "
              "exchange")

    def churn(t, rng):
        return rng.random(k) < args.p_stay

    attacks = None
    if args.byzantine:
        nodes = tuple(int(n) for n in args.byzantine.split(","))
        attacks = [attack.Byzantine(nodes=nodes, mode="sign_flip",
                                    scale=10.0, start=5)]
        print(f"byzantine nodes {nodes}: sign-flip x10 from round 5 "
              f"(defense: {args.robust or 'NONE — trusting the wire'})")

    cadence = metrics_lib.AdaptiveCadence(base=1, max_every=64, grow=2,
                                          near=2.0)
    res = run_cola(prob, graph,
                   ColaConfig(kappa=2.0, robust=args.robust, wire=args.wire,
                              error_feedback=not args.no_error_feedback,
                              telemetry=telemetry),
                   rounds=args.rounds,
                   record_every=cadence, recorder="gap+certificate",
                   eps=args.eps, active_schedule=churn, leave_mode="freeze",
                   attacks=attacks)
    h = res.history
    print(f"p_stay={args.p_stay} topo={graph.name}: suboptimality "
          "trajectory (adaptive record cadence)")
    for t, p in zip(h["round"][::5], h["primal"][::5]):
        print(f"  round {t:4d}  F_A - F* = {p - opt:10.6f}")
    print(f"recorded {len(h['round'])} rows over {h['round'][-1] + 1} rounds"
          f" (fixed record_every=20 would have recorded "
          f"{(h['round'][-1] // 20) + 1})")
    if attacks is not None:
        if h["violated_round"] is not None:
            print(f"CERTIFICATE VIOLATED at round {h['violated_round']}: "
                  "the honest cohort's invariant was tampered with — "
                  "results untrusted (try --robust trim)")
        else:
            print("honest-cohort certificate sound: the defense held")
    if h["stop_round"] is not None:
        print(f"certified eps={args.eps} at round {h['stop_round']} "
              f"(true gap {h['gap'][-1]:.4f}) — stopped "
              f"{args.rounds - h['stop_round'] - 1} rounds early")
    else:
        print(f"budget exhausted before certifying eps={args.eps} "
              f"(gap {h['gap'][-1]:.4f})")

    x_final = res.state.x_parts.reshape(-1)[: prob.n]
    nnz = int(np.sum(np.abs(np.asarray(x_final)) > 1e-6))
    print(f"solution sparsity: {nnz}/{prob.n} nonzeros")

    if telemetry:
        tel = h["telemetry"]
        print(f"telemetry: {tel['rounds']} rounds moved "
              f"{tel['wire_bytes']:.0f} wire bytes "
              f"({tel['permutes']} ppermutes) — contract: {tel['contract']}")
        if args.robust:
            msg = f"  robust gate: {tel['gate_total']} payload rejections"
            if "gate_dishonest" in tel:
                msg += (f" (honest senders {tel['gate_honest']}, "
                        f"dishonest {tel['gate_dishonest']})")
            print(msg)
        if quantized:
            print(f"  codec: mean saturation {tel['saturation_mean']:.4f}, "
                  f"final EF residual norm {tel['ef_norm']:.4f}")
        if args.report:
            from repro.obs import report as obs_report
            print(f"report appended to {obs_report.runs_file()} — inspect "
                  "with: python -m repro.obs show -1 (or diff/timeline)")


if __name__ == "__main__":
    main()
