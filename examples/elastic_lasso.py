"""Elastic decentralized LASSO: nodes drop out and re-join mid-training.

Reproduces the Fig.-4 fault-tolerance setting in miniature: every round each
node stays in the network with probability p; leavers freeze their block
(Theta_k = 1) and the surviving nodes re-normalize the Metropolis weights.
CoLA keeps converging monotonically — no tuning, no restart.

  PYTHONPATH=src python examples/elastic_lasso.py [--p-stay 0.8]
"""
import argparse

import jax.numpy as jnp
import numpy as np

from repro.core import problems, topology as topo
from repro.core.cola import ColaConfig, run_cola, solve_reference
from repro.data import synthetic


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--p-stay", type=float, default=0.8)
    ap.add_argument("--rounds", type=int, default=200)
    args = ap.parse_args()

    x, y, _ = synthetic.regression(1500, 300, seed=1, sparsity_solution=0.1)
    prob = problems.lasso(jnp.asarray(x), jnp.asarray(y), lam=1e-3)
    opt = solve_reference(prob, rounds=500, kappa=8)
    graph = topo.connected_cycle(16, 2)

    def churn(t, rng):
        return rng.random(16) < args.p_stay

    res = run_cola(prob, graph, ColaConfig(kappa=2.0), rounds=args.rounds,
                   record_every=args.rounds // 10,
                   active_schedule=churn, leave_mode="freeze")
    print(f"p_stay={args.p_stay}: suboptimality trajectory")
    for t, p in zip(res.history["round"], res.history["primal"]):
        print(f"  round {t:4d}  F_A - F* = {p - opt:10.6f}")

    x_final = res.state.x_parts.reshape(-1)[: prob.n]
    nnz = int(np.sum(np.abs(np.asarray(x_final)) > 1e-6))
    print(f"solution sparsity: {nnz}/{prob.n} nonzeros")


if __name__ == "__main__":
    main()
