"""Quickstart: decentralized ridge regression with CoLA (Algorithm 1).

16 nodes on a ring, no central coordinator, parameter-free defaults
(gamma = 1, sigma' = K). Prints the decentralized duality gap + consensus
violation per round and finishes with the Prop.-1 LOCAL certificate — each
node certifies the GLOBAL duality gap from its own neighborhood only.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp

from repro.core import problems, topology as topo
from repro.core.cola import ColaConfig, build_env, run_cola
from repro.core.duality import block_spectral_norms, local_certificates
from repro.core.partition import make_partition
from repro.data import synthetic


def main() -> None:
    # data: dense synthetic regression, columns (features) spread over nodes
    x, y, _ = synthetic.regression(2000, 400, seed=0)
    prob = problems.ridge_primal(jnp.asarray(x), jnp.asarray(y), lam=1e-4)

    graph = topo.ring(16)
    w = topo.metropolis_weights(graph)
    print(f"ring of {graph.num_nodes}: beta={topo.beta(w):.4f} "
          f"(spectral gap {topo.spectral_gap(w):.4f})")

    res = run_cola(prob, graph, ColaConfig(kappa=2.0), rounds=200,
                   record_every=25)
    for t, p, g, cv in zip(res.history["round"], res.history["primal"],
                           res.history["gap"],
                           res.history["consensus_violation"]):
        print(f"round {t:4d}  F_A={p:10.4f}  gap={g:10.4f}  "
              f"consensus-violation={cv:.3e}")

    # Prop. 1 requires L-bounded support of g_i (lasso-type); certify a
    # lasso run — each node checks the GLOBAL gap from local quantities.
    # (The certificate's condition 10 is conservative by the worst-case
    # factor sqrt(K sum n_k^2 sigma_k)/(1-beta), so it fires once the run is
    # well past the target accuracy — use a smaller instance to get there.)
    lx, ly, _ = synthetic.regression(800, 96, seed=3, sparsity_solution=0.2)
    lprob = problems.lasso(jnp.asarray(lx), jnp.asarray(ly), lam=5e-2,
                           box=5.0)
    lres = run_cola(lprob, graph, ColaConfig(kappa=8.0), rounds=2500,
                    record_every=2499)
    part = make_partition(lprob.n, graph.num_nodes)
    env = build_env(lprob, part)
    # f32 gradient-disagreement noise floor is ~1e-6; the conservative
    # condition-10 scaling maps that to a certifiable eps of ~1e-1 here.
    eps = max(10.0 * lres.history["gap"][-1], 1e-1)
    cert = local_certificates(
        lprob, part, lres.state.x_parts, lres.state.v_stack, env.a_parts,
        env.gp_parts, env.masks, graph.adjacency, topo.beta(w),
        block_spectral_norms(env.a_parts), eps, lprob.l_bound)
    print(f"\nlasso true gap {lres.history['gap'][-1]:.4f}; local "
          f"certificate for eps={eps:.4f}: certified={bool(cert.certified)} "
          f"(condition 9 on {int(cert.local_gap_ok.sum())}/16 nodes, "
          f"condition 10 on {int(cert.grad_ok.sum())}/16)")


if __name__ == "__main__":
    main()
