"""Quickstart: decentralized ridge regression with CoLA (Algorithm 1).

16 nodes on a ring, no central coordinator, parameter-free defaults
(gamma = 1, sigma' = K). Prints the decentralized duality gap + consensus
violation per round, then runs a lasso with CERTIFICATE-DRIVEN stopping:
``eps=`` arms the Prop.-1 local certificates — each node certifies the
GLOBAL duality gap from its own neighborhood only, and the run stops at
the first record round where every node passes, instead of burning a
fixed round budget.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp

from repro.core import problems, topology as topo
from repro.core.cola import ColaConfig, run_cola
from repro.data import synthetic


def main() -> None:
    # data: dense synthetic regression, columns (features) spread over nodes
    x, y, _ = synthetic.regression(2000, 400, seed=0)
    prob = problems.ridge_primal(jnp.asarray(x), jnp.asarray(y), lam=1e-4)

    graph = topo.ring(16)
    w = topo.metropolis_weights(graph)
    print(f"ring of {graph.num_nodes}: beta={topo.beta(w):.4f} "
          f"(spectral gap {topo.spectral_gap(w):.4f})")

    res = run_cola(prob, graph, ColaConfig(kappa=2.0), rounds=200,
                   record_every=25)
    for t, p, g, cv in zip(res.history["round"], res.history["primal"],
                           res.history["gap"],
                           res.history["consensus_violation"]):
        print(f"round {t:4d}  F_A={p:10.4f}  gap={g:10.4f}  "
              f"consensus-violation={cv:.3e}")

    # Prop. 1 requires L-bounded support of g_i (lasso-type); certify a
    # lasso run — each node checks the GLOBAL gap from local quantities
    # (one gossip exchange of neighbor gradients), and the driver stops at
    # certification. The certificate's condition 10 is conservative by the
    # worst-case factor sqrt(K sum n_k^2 sigma_k)/(1-beta), so it fires
    # once the run is well past the target accuracy; the f32 gradient-
    # disagreement noise floor maps to a certifiable eps of ~1e-1 here.
    lx, ly, _ = synthetic.regression(800, 96, seed=3, sparsity_solution=0.2)
    lprob = problems.lasso(jnp.asarray(lx), jnp.asarray(ly), lam=5e-2,
                           box=5.0)
    eps = 0.1
    budget = 4000
    lres = run_cola(lprob, graph, ColaConfig(kappa=8.0), rounds=budget,
                    record_every=50, recorder="gap+certificate", eps=eps)
    h = lres.history
    stopped = h["stop_round"]
    if stopped is None:
        print(f"\nlasso, eps={eps}: budget of {budget} rounds exhausted "
              f"without certification (gap {h['gap'][-1]:.6f}, condition 9 "
              f"on {int(h['cond9_nodes'][-1])}/16 nodes, condition 10 on "
              f"{int(h['cond10_nodes'][-1])}/16)")
        return
    print(f"\nlasso, eps={eps}: certified at round {stopped} "
          f"(budget {budget}; {len(h['round'])} record rounds kept)")
    print(f"  true gap at certification: {h['gap'][-1]:.6f} <= eps"
          f"  (condition 9 on {int(h['cond9_nodes'][-1])}/16 nodes, "
          f"condition 10 on {int(h['cond10_nodes'][-1])}/16)")


if __name__ == "__main__":
    main()
