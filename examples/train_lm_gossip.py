"""End-to-end driver: train the ~125M-parameter xLSTM on synthetic tokens —
once with canonical all-reduce data parallelism, once with CoLA-style gossip
data parallelism (4 node replicas on a ring, Metropolis parameter mixing, no
global collective), and compare loss + consensus trajectories.

Full-size run (slow on CPU):
  PYTHONPATH=src python examples/train_lm_gossip.py --steps 300
Quick demo (reduced config):
  PYTHONPATH=src python examples/train_lm_gossip.py --smoke --steps 30
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, smoke_variant
from repro.optim import gossip as gsp
from repro.train.data import TokenBatches
from repro.train.steps import TrainHParams, init_train_state, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    cfg = get_config("xlstm_125m")
    if args.smoke:
        cfg = smoke_variant(cfg)
    hp = TrainHParams(lr=1e-3)
    pipe = TokenBatches(cfg.vocab_size, args.batch, args.seq, seed=0)
    print(f"model: {cfg.name} ({'smoke' if args.smoke else 'full ~125M'})")

    # --- baseline: single-replica (== all-reduce DP semantics) -------------
    state = init_train_state(cfg, jax.random.PRNGKey(0), hp)
    step = jax.jit(make_train_step(cfg, hp))
    t0 = time.time()
    for i in range(args.steps):
        state, m = step(state, jax.tree.map(jnp.asarray, pipe(i)))
        if i % max(args.steps // 10, 1) == 0:
            print(f"[all-reduce] step {i:4d} loss {float(m['loss']):.4f} "
                  f"({(time.time()-t0)/(i+1):.2f}s/step)", flush=True)
    base_loss = float(m["loss"])

    # --- CoLA gossip-DP: K replicas, ring mixing, node-local data ----------
    k = args.nodes
    gcfg = gsp.GossipConfig(num_nodes=k, topology="ring")
    states = gsp.replicate_state(init_train_state(cfg, jax.random.PRNGKey(0),
                                                  hp), k)
    gstep = gsp.make_gossip_step(make_train_step(cfg, hp), gcfg)
    w = jnp.asarray(gcfg.weights(), jnp.float32)
    act = jnp.ones((k,), jnp.float32)
    t0 = time.time()
    for i in range(args.steps):
        batches = jax.tree.map(
            jnp.asarray, jax.tree.map(lambda *xs: np.stack(xs),
                                      *[pipe(i, shard=j) for j in range(k)]))
        states, m = gstep(states, batches, w, act)
        if i % max(args.steps // 10, 1) == 0:
            print(f"[gossip-DP ] round {i:4d} mean-loss "
                  f"{float(jnp.mean(m['loss'])):.4f} consensus "
                  f"{float(gsp.consensus_distance(states.params)):.3e} "
                  f"({(time.time()-t0)/(i+1):.2f}s/round)", flush=True)
    print(f"\nfinal: all-reduce loss {base_loss:.4f} | gossip mean loss "
          f"{float(jnp.mean(m['loss'])):.4f} (each gossip node saw {k}x the "
          f"data at 1/{k} the per-round collective cost)")


if __name__ == "__main__":
    main()
