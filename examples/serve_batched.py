"""Batched serving across architecture families: prefill a batch of prompts
and stream decode steps for a dense (SWA), an SSM and a hybrid model —
demonstrating the ring-buffer KV cache and O(1) recurrent decode state.

  PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_config, smoke_variant
from repro.models.model import build_model


def serve(arch: str, batch: int = 4, prompt_len: int = 32, gen: int = 16):
    cfg = smoke_variant(get_config(arch))
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (batch, prompt_len), 0, cfg.vocab_size)
    cache = api.init_cache(params, batch, prompt_len + gen)
    decode = jax.jit(api.decode_step)

    t0 = time.time()
    logits, cache = api.prefill(params, {"tokens": prompt}, cache)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    t_pre = time.time() - t0
    t0 = time.time()
    for i in range(gen - 1):
        logits, cache = decode(params, tok,
                               jnp.asarray(prompt_len + i, jnp.int32), cache)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    jax.block_until_ready(tok)
    t_dec = (time.time() - t0) / max(gen - 1, 1)
    state_bytes = sum(x.size * x.dtype.itemsize
                      for x in jax.tree.leaves(cache))
    print(f"{arch:18s} [{cfg.family:6s}] prefill {t_pre*1e3:7.1f} ms | "
          f"decode {t_dec*1e3:6.1f} ms/tok | decode-state "
          f"{state_bytes/1e6:6.2f} MB")


def main() -> None:
    for arch in ("h2o_danube3_4b", "xlstm_125m", "zamba2_7b"):
        serve(arch)


if __name__ == "__main__":
    main()
